//! Tables 1 and 2: runtime-classifier comparison (paper §5.1) — % of the
//! absolute optimal performance achieved by each classifier's choices, for
//! PCA+K-means selections of 5/6/8/15 kernel configurations.

use crate::classify::{classifier_percent, ALL_CLASSIFIERS};
use crate::selection::{achievable_percent, select, Method};
use crate::util::table::{fnum, Table};

use super::selection_figs::DEPLOY_NORM;
use super::Context;

/// Deployment sizes (k) forming the columns of Tables 1/2.
pub const K_COLUMNS: [usize; 4] = [5, 6, 8, 15];

fn classifier_table(ctx: &Context, device: &str, tab: &str) -> Vec<Table> {
    let ds = ctx.dataset(device);
    let split = ds.split(0.8, ctx.seed);
    let train = ds.subset(&split.train);
    let test = ds.subset(&split.test);

    // One PCA+K-means deployment per k column.
    let deployments: Vec<Vec<usize>> = K_COLUMNS
        .iter()
        .map(|&k| select(Method::PcaKMeans, &train, DEPLOY_NORM, k, ctx.seed))
        .collect();
    let maxima: Vec<f64> = deployments
        .iter()
        .map(|d| achievable_percent(&test, d))
        .collect();

    let mut t = Table::new(
        &format!(
            "{tab}: classifier % of absolute optimal, PCA+K-means selections ({device} sim)"
        ),
        &["Classifier", "5", "6", "8", "15"],
    );
    for kind in ALL_CLASSIFIERS {
        let mut row = vec![kind.name().to_string()];
        for dep in &deployments {
            row.push(fnum(
                classifier_percent(kind, &train, &test, dep, ctx.seed),
                2,
            ));
        }
        t.row(row);
    }
    t.note(&format!(
        "maximum achievable for the selections: {} (paper Table {} maxima: \
         91.19/94.62/94.94/96.89 AMD, 96.55/96.65/97.34/97.95 Intel)",
        maxima.iter().map(|m| fnum(*m, 2)).collect::<Vec<_>>().join("/"),
        if tab.contains('1') { "1" } else { "2" },
    ));
    vec![t]
}

/// Table 1: AMD R9 Nano.
pub fn tab1(ctx: &Context) -> Vec<Table> {
    classifier_table(ctx, "r9-nano", "Table 1")
}

/// Table 2: Intel i7-6700K.
pub fn tab2(ctx: &Context) -> Vec<Table> {
    classifier_table(ctx, "i7-6700k", "Table 2")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_shape_and_sanity() {
        let ctx = Context::with_stride(7, 3);
        let t = &tab2(&ctx)[0];
        assert_eq!(t.rows.len(), 10);
        assert_eq!(t.headers.len(), 5);
        for row in &t.rows {
            for cell in &row[1..] {
                let v: f64 = cell.parse().unwrap();
                assert!((5.0..=100.0).contains(&v), "{}: {v}", row[0]);
            }
        }
    }

    #[test]
    fn decision_trees_competitive() {
        // The paper's §5 conclusion: decision trees perform well, often
        // better than costlier methods. Require DT-A to be within 12% of
        // the best classifier in the k=6 column and to beat the MLP.
        let ctx = Context::with_stride(7, 3);
        let t = &tab1(&ctx)[0];
        let col = 2; // k=6
        let get = |name: &str| -> f64 {
            t.rows
                .iter()
                .find(|r| r[0] == name)
                .unwrap()[col]
                .parse()
                .unwrap()
        };
        let dta = get("DecisionTreeA");
        let best = t
            .rows
            .iter()
            .map(|r| r[col].parse::<f64>().unwrap())
            .fold(0.0f64, f64::max);
        assert!(dta > best - 12.0, "DT-A {dta} vs best {best}");
        assert!(dta > get("MLP") - 2.0, "DT-A {dta} vs MLP {}", get("MLP"));
    }
}
