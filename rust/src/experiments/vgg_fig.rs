//! Figure 7: VGG16 single-image inference time across devices and GEMM
//! backends (paper §6).
//!
//! Two parts:
//!  * **Simulated devices** — the four paper devices, with mechanistic
//!    models of the comparator libraries (DESIGN.md §3):
//!      - `sycl-dnn-tuned`: the paper's system — 8 PCA+K-means kernels +
//!        decision-tree selection, tuned per device;
//!      - `clblast-sim`: one kernel per device, chosen by tuning on square
//!        1024^2/256^2 matrices only (how CLBlast's tuner works, §6.1);
//!      - `sycl-blas-sim`: per-layer best kernels *as tuned for the R9
//!        Nano* (the library's main optimization target, §6.2), with a
//!        local-memory bonus only on the discrete GPU (Mali/CPU "local"
//!        memory is just system RAM).
//!  * **Measured (local CPU PJRT)** — real end-to-end inference through the
//!    Rust runtime on vgg16-tiny artifacts for the three shipped backends.

use std::path::Path;

use crate::classify::codegen::CompiledTree;
use crate::classify::{ClassifierKind, KernelClassifier};
#[cfg(feature = "pjrt")]
use crate::coordinator::{SelectorPolicy, VggEngine};
use crate::dataset::shapes::vgg16_gemms;
use crate::dataset::{all_configs, GemmShape, KernelConfig};
use crate::devsim::{profile_by_name, simulate, DeviceProfile};
#[cfg(feature = "pjrt")]
use crate::runtime::{Manifest, Runtime};
use crate::selection::{select, Method};
use crate::util::table::{fnum, Table};

use super::selection_figs::DEPLOY_NORM;
use super::Context;

/// Simulated inference time (ms) of the full VGG16 layer sequence when
/// `config_for` picks the kernel per layer GEMM.
fn sim_inference_ms(
    profile: &DeviceProfile,
    mut config_for: impl FnMut(&GemmShape) -> KernelConfig,
    lds_bonus: f64,
) -> f64 {
    let mut total_ms = 0.0;
    for g in vgg16_gemms() {
        let cfg = config_for(&g);
        let gflops = simulate(profile, &g, &cfg) * lds_bonus;
        total_ms += g.flops() / (gflops * 1e9) * 1e3;
        total_ms += profile.kernel_launch_us * 1e-3;
    }
    total_ms
}

/// Best config for a shape by direct simulation on a device.
fn sim_oracle(profile: &DeviceProfile, shape: &GemmShape) -> KernelConfig {
    let mut best = all_configs()[0];
    let mut best_g = -1.0;
    for cfg in all_configs() {
        let g = simulate(profile, shape, &cfg);
        if g > best_g {
            best_g = g;
            best = cfg;
        }
    }
    best
}

/// CLBlast-style single kernel: tuned on square matrices only.
fn clblast_config(profile: &DeviceProfile) -> KernelConfig {
    let tuning = [GemmShape::new(1024, 1024, 1024, 1), GemmShape::new(256, 256, 256, 1)];
    let mut best = all_configs()[0];
    let mut best_score = -1.0;
    for cfg in all_configs() {
        let score: f64 = tuning.iter().map(|s| simulate(profile, s, &cfg)).sum();
        if score > best_score {
            best_score = score;
            best = cfg;
        }
    }
    best
}

/// Figure 7: VGG-16 end-to-end — the simulated comparison always, plus the
/// measured (PJRT) table when artifacts are available, else a skip notice.
pub fn fig7(ctx: &Context, artifacts_dir: &Path) -> Result<Vec<Table>, String> {
    let mut tables = vec![simulated_table(ctx)];
    match measured_table(ctx, artifacts_dir) {
        Ok(t) => tables.push(t),
        Err(e) => {
            let mut t = Table::new("Fig 7 (measured): skipped", &["reason"]);
            t.row(vec![e]);
            tables.push(t);
        }
    }
    Ok(tables)
}

fn simulated_table(ctx: &Context) -> Table {
    let mut t = Table::new(
        "Fig 7: VGG16 inference time, simulated devices (ms, lower is better)",
        &["device", "sycl-dnn-tuned", "sycl-blas-sim", "clblast-sim", "tuned distinct cfgs"],
    );
    let nano = profile_by_name("r9-nano").unwrap();
    for device in ["r9-nano", "i7-6700k", "hd530", "mali-g71"] {
        let profile = profile_by_name(device).unwrap();
        let ds = ctx.dataset(device);

        // The paper's system: 8 kernels + decision tree, tuned per device.
        let deployed = select(Method::PcaKMeans, &ds, DEPLOY_NORM, 8, ctx.seed);
        let clf =
            KernelClassifier::fit(ClassifierKind::DecisionTreeB, &ds, &deployed, ctx.seed);
        let tree = CompiledTree::compile(&clf).expect("tree");
        let mut used = std::collections::HashSet::new();
        let tuned = sim_inference_ms(
            profile,
            |g| {
                let cfg = crate::dataset::config_by_index(tree.predict_config(&g.features()));
                used.insert(cfg.index());
                cfg
            },
            1.0,
        );

        // SYCL-BLAS: hand-tuned for the R9 Nano; LDS bonus on discrete GPU.
        let lds = if matches!(profile.kind, crate::devsim::profiles::DeviceKind::DiscreteGpu) {
            1.25
        } else {
            1.0
        };
        let syclblas = sim_inference_ms(profile, |g| sim_oracle(nano, g), lds);

        // CLBlast: one kernel tuned on square sizes for this device.
        let single = clblast_config(profile);
        let clblast = sim_inference_ms(profile, |_| single, 1.0);

        t.row(vec![
            device.to_string(),
            fnum(tuned, 1),
            fnum(syclblas, 1),
            fnum(clblast, 1),
            used.len().to_string(),
        ]);
    }
    t.note("paper landmarks: R9 Nano <20ms with the optimized libraries and \
            SYCL-DNN close; CPU + HD530: SYCL-DNN fastest; Mali: SYCL-DNN \
            <400ms vs >700ms for both libraries");
    t
}

/// Without native PJRT there is nothing to measure; fig7 renders the skip
/// reason in place of the measured table.
#[cfg(not(feature = "pjrt"))]
fn measured_table(_ctx: &Context, _artifacts_dir: &Path) -> Result<Table, String> {
    Err("built without the `pjrt` feature".to_string())
}

#[cfg(feature = "pjrt")]
fn measured_table(ctx: &Context, artifacts_dir: &Path) -> Result<Table, String> {
    let runtime = Runtime::new(artifacts_dir)?;
    let manifest = Manifest::load(artifacts_dir)?;
    let image = crate::util::fill_buffer(99, 32 * 32 * 3);

    // Tune the tree over the shipped deployment, on measured local-CPU
    // data when `kernelsel collect` has been run, else on the simulated
    // CPU dataset.
    let measured = Path::new("results/measured_cpu.csv");
    let ds = if measured.exists() {
        std::rc::Rc::new(
            crate::dataset::PerfDataset::load("local-cpu", measured)
                .map_err(|e| e.to_string())?,
        )
    } else {
        ctx.dataset("i7-6700k")
    };
    let deployed: Vec<usize> = manifest
        .deployed
        .iter()
        .map(|n| crate::dataset::config_by_name(n).unwrap().index())
        .collect();
    let clf = KernelClassifier::fit(ClassifierKind::DecisionTreeB, &ds, &deployed, ctx.seed);
    let tree = CompiledTree::compile(&clf).expect("tree");
    let single = crate::dataset::config_by_name(&manifest.single_best)
        .unwrap()
        .index();

    let mut t = Table::new(
        "Fig 7 (measured): vgg16-tiny inference on local CPU PJRT (ms)",
        &["backend", "mean ms", "min ms", "distinct cfgs"],
    );
    for policy in [
        SelectorPolicy::Tree(tree),
        SelectorPolicy::Single(single),
        SelectorPolicy::Xla,
    ] {
        let name = policy.name().to_string();
        let engine = VggEngine::load(&runtime, &manifest, "vgg16-tiny", &policy)
            .map_err(|e| e.to_string())?;
        // Warmup, then a few timed inferences.
        engine.infer(&image).map_err(|e| e.to_string())?;
        let mut times = Vec::new();
        for _ in 0..5 {
            let t0 = std::time::Instant::now();
            engine.infer(&image).map_err(|e| e.to_string())?;
            times.push(t0.elapsed().as_secs_f64() * 1e3);
        }
        let mean = times.iter().sum::<f64>() / times.len() as f64;
        let min = times.iter().cloned().fold(f64::INFINITY, f64::min);
        t.row(vec![
            name,
            fnum(mean, 2),
            fnum(min, 2),
            engine.distinct_configs().to_string(),
        ]);
    }
    t.note("single image, weights resident, Pallas interpret-lowered kernels vs XLA dot");
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn simulated_fig7_reproduces_crossover() {
        let ctx = Context::with_stride(7, 3);
        let t = simulated_table(&ctx);
        assert_eq!(t.rows.len(), 4);
        let get = |dev: &str, col: usize| -> f64 {
            t.rows
                .iter()
                .find(|r| r[0] == dev)
                .unwrap()[col]
                .parse()
                .unwrap()
        };
        // R9 Nano: the hand-optimized library wins (paper: SYCL-BLAS best).
        assert!(get("r9-nano", 2) < get("r9-nano", 1));
        // CPU and Mali: the tuned multi-kernel library wins.
        assert!(get("i7-6700k", 1) < get("i7-6700k", 3), "CPU: tuned vs clblast");
        assert!(get("mali-g71", 1) < get("mali-g71", 2), "Mali: tuned vs syclblas");
        assert!(get("mali-g71", 1) < get("mali-g71", 3), "Mali: tuned vs clblast");
        // The tuned engine uses several distinct kernels.
        let used: usize = t.rows[3][4].parse().unwrap();
        assert!(used >= 2);
    }

    #[test]
    fn clblast_config_is_square_biased() {
        let profile = profile_by_name("r9-nano").unwrap();
        let cfg = clblast_config(profile);
        // Tuned on big squares: expect a reasonably large output block.
        assert!(cfg.block_m() * cfg.block_n() >= 256, "{}", cfg.name());
    }
}
