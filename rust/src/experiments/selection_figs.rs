//! Figures 5 and 6: the pruning-technique comparison — achievable % of
//! optimal vs number of deployed kernels, per selection method and
//! normalization scheme (paper §4.3).

use crate::dataset::{Normalization, ALL_NORMALIZATIONS};
use crate::selection::{achievable_percent, select, ALL_METHODS};
use crate::util::table::{fnum, Table};

use super::Context;

/// Deployed-kernel counts swept by Figures 5/6 (the paper's x-axis).
pub const K_RANGE: [usize; 7] = [4, 5, 6, 8, 10, 12, 15];

fn selection_figure(ctx: &Context, device: &str, fig: &str) -> Vec<Table> {
    let ds = ctx.dataset(device);
    let split = ds.split(0.8, ctx.seed);
    let train = ds.subset(&split.train);
    let test = ds.subset(&split.test);

    let mut tables = Vec::new();
    for norm in ALL_NORMALIZATIONS {
        let mut headers: Vec<&str> = vec!["k"];
        headers.extend(ALL_METHODS.iter().map(|m| m.name()));
        let mut t = Table::new(
            &format!(
                "{fig}: % of optimal vs #kernels, {} normalization ({device} sim)",
                norm.name()
            ),
            &headers,
        );
        for &k in &K_RANGE {
            let mut row = vec![k.to_string()];
            for method in ALL_METHODS {
                let picks = select(method, &train, norm, k, ctx.seed);
                row.push(fnum(achievable_percent(&test, &picks), 2));
            }
            t.row(row);
        }
        t.note("oracle pick among deployed kernels; geometric mean over the test split");
        tables.push(t);
    }
    tables
}

/// Figure 5: AMD R9 Nano.
pub fn fig5(ctx: &Context) -> Vec<Table> {
    selection_figure(ctx, "r9-nano", "Fig 5")
}

/// Figure 6: Intel i7-6700K.
pub fn fig6(ctx: &Context) -> Vec<Table> {
    selection_figure(ctx, "i7-6700k", "Fig 6")
}

/// The normalization used downstream by Tables 1/2 and the deployment
/// pipeline (the paper's most stable combination).
pub const DEPLOY_NORM: Normalization = Normalization::Standard;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig5_structure_and_trends() {
        let ctx = Context::with_stride(7, 3);
        let tables = fig5(&ctx);
        assert_eq!(tables.len(), 4); // one per normalization
        let std_table = &tables[0];
        assert_eq!(std_table.rows.len(), K_RANGE.len());
        // K-means at k=15 must beat K-means at k=4 (more kernels help the
        // oracle), and everything must be a sane percentage.
        let col = 2; // KMeans column
        let at_k4: f64 = std_table.rows[0][col].parse().unwrap();
        let at_k15: f64 = std_table.rows[K_RANGE.len() - 1][col].parse().unwrap();
        assert!(at_k15 >= at_k4 - 1.0, "k=15 {at_k15} < k=4 {at_k4}");
        for row in &std_table.rows {
            for cell in &row[1..] {
                let v: f64 = cell.parse().unwrap();
                assert!((10.0..=100.0).contains(&v), "{v}");
            }
        }
    }

    #[test]
    fn clustering_over_90_pct_with_few_kernels() {
        // The paper's abstract claim: >90% of optimal with as few kernels
        // as 4-6 using clustering methods.
        let ctx = Context::with_stride(7, 3);
        let tables = fig6(&ctx);
        let std_table = &tables[0];
        // k=6 row, KMeans column. (On the full, unstrided dataset this
        // lands at >93%, matching the paper's >90% claim; the strided test
        // dataset trades a few points for speed.)
        let v: f64 = std_table.rows[2][2].parse().unwrap();
        assert!(v > 80.0, "KMeans at k=6 only {v}%");
    }
}
