//! Kernel-subset selection (paper §4): choose the k configurations a
//! library should deploy, from the benchmark dataset alone.
//!
//! Implements the paper's six methods: the Top-N baseline (§4.2), K-means,
//! PCA+K-means, spectral clustering, HDBSCAN (with the hyperparameter sweep)
//! and the decision-tree-with-bounded-leaves clusterer (§4.1.5). Clustering
//! methods represent each size set as its (normalized) 640-dim performance
//! vector; each cluster contributes the configuration that maximizes the
//! geometric mean of the cluster members' normalized performance.

pub mod evaluate;

pub use evaluate::{achievable_percent, achieved_percent, evaluate_selection};

use crate::dataset::{Normalization, PerfDataset, NUM_CONFIGS};
use crate::linalg::stats::argmax;
use crate::linalg::Matrix;
use crate::ml::decision_tree::{TreeParams, TreeRegressor};
use crate::ml::hdbscan::sweep_for_k;
use crate::ml::kmeans::{kmeans, KMeansParams};
use crate::ml::pca::Pca;
use crate::ml::spectral::{spectral, SpectralParams};

/// Selection methods of paper §4.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Method {
    /// Top-N baseline (§4.2): configurations that win the most size sets.
    TopN,
    /// K-means on the normalized 640-dim performance vectors.
    KMeans,
    /// PCA to 15 components, then K-means on the scores.
    PcaKMeans,
    /// Spectral clustering on the performance-vector similarity graph.
    Spectral,
    /// HDBSCAN with the paper's hyperparameter sweep targeting k clusters.
    Hdbscan,
    /// Decision-tree regressor with at most k leaves (§4.1.5); each leaf
    /// is treated as a cluster.
    DecisionTree,
}

/// Every selection method, in the paper's presentation order — iterate
/// this to run the full comparison table.
pub const ALL_METHODS: [Method; 6] = [
    Method::TopN,
    Method::KMeans,
    Method::PcaKMeans,
    Method::Spectral,
    Method::Hdbscan,
    Method::DecisionTree,
];

impl Method {
    /// Stable display name (matches the paper's figure labels and the
    /// CLI/JSON spelling).
    pub fn name(&self) -> &'static str {
        match self {
            Method::TopN => "TopN",
            Method::KMeans => "KMeans",
            Method::PcaKMeans => "PCA+KMeans",
            Method::Spectral => "Spectral",
            Method::Hdbscan => "HDBScan",
            Method::DecisionTree => "DecisionTree",
        }
    }

    /// Inverse of [`Method::name`], case-insensitive; `None` for an
    /// unknown method name.
    pub fn by_name(name: &str) -> Option<Method> {
        ALL_METHODS.iter().copied().find(|m| m.name().eq_ignore_ascii_case(name))
    }
}

/// Select `k` distinct configuration indices to deploy, learning only from
/// `train` under normalization `norm`.
pub fn select(
    method: Method,
    train: &PerfDataset,
    norm: Normalization,
    k: usize,
    seed: u64,
) -> Vec<usize> {
    assert!(k >= 1 && k <= NUM_CONFIGS);
    let normalized = train.normalized(norm);
    let mut picks = match method {
        Method::TopN => top_n(train, k),
        Method::KMeans => {
            let fit = kmeans(&normalized, &KMeansParams::new(k.min(normalized.rows)).seed(seed));
            picks_from_labels(&normalized, &to_opt_labels(&fit.labels), k)
        }
        Method::PcaKMeans => {
            let pca = Pca::fit(&normalized, 15);
            let scores = pca.transform(&normalized);
            let fit = kmeans(&scores, &KMeansParams::new(k.min(scores.rows)).seed(seed));
            picks_from_labels(&normalized, &to_opt_labels(&fit.labels), k)
        }
        Method::Spectral => {
            let fit = spectral(&normalized, &SpectralParams::new(k.min(normalized.rows)).seed(seed));
            picks_from_labels(&normalized, &to_opt_labels(&fit.labels), k)
        }
        Method::Hdbscan => {
            let (fit, _params) = sweep_for_k(&normalized, k);
            let labels: Vec<Option<usize>> = fit
                .labels
                .iter()
                .map(|&l| if l < 0 { None } else { Some(l as usize) })
                .collect();
            picks_from_labels(&normalized, &labels, k)
        }
        Method::DecisionTree => {
            let features = train.features();
            let params = TreeParams { max_leaves: Some(k), ..Default::default() };
            let tree = TreeRegressor::fit(&features, &normalized, &params);
            let mut picks = Vec::new();
            for leaf in 0..tree.n_leaves() {
                push_unique(&mut picks, ranked_configs(&tree.leaf_values[leaf]));
            }
            picks
        }
    };
    fill_to_k(&mut picks, train, k);
    picks.truncate(k);
    picks
}

fn to_opt_labels(labels: &[usize]) -> Vec<Option<usize>> {
    labels.iter().map(|&l| Some(l)).collect()
}

/// Top-N baseline: the configurations that win the most size sets
/// (ties broken by total normalized performance).
fn top_n(train: &PerfDataset, k: usize) -> Vec<usize> {
    let counts = train.winner_counts();
    let norm = train.normalized(Normalization::Standard);
    let mut totals = vec![0.0f64; NUM_CONFIGS];
    for r in 0..norm.rows {
        for (t, &v) in totals.iter_mut().zip(norm.row(r)) {
            *t += v;
        }
    }
    let mut order: Vec<usize> = (0..NUM_CONFIGS).collect();
    order.sort_by(|&a, &b| {
        counts[b]
            .cmp(&counts[a])
            .then(totals[b].partial_cmp(&totals[a]).unwrap())
    });
    order.truncate(k);
    order
}

/// For each cluster, rank configurations by the geometric mean of the
/// members' normalized performance and take the best not yet chosen.
fn picks_from_labels(
    normalized: &Matrix,
    labels: &[Option<usize>],
    _k: usize,
) -> Vec<usize> {
    let n_clusters = labels.iter().flatten().max().map_or(0, |&m| m + 1);
    let mut picks: Vec<usize> = Vec::new();
    for cluster in 0..n_clusters {
        let members: Vec<usize> = (0..normalized.rows)
            .filter(|&r| labels[r] == Some(cluster))
            .collect();
        if members.is_empty() {
            continue;
        }
        let gm = geomean_profile(normalized, &members);
        push_unique(&mut picks, ranked_configs(&gm));
    }
    picks
}

/// Geometric-mean performance profile of a set of rows.
fn geomean_profile(normalized: &Matrix, members: &[usize]) -> Vec<f64> {
    let eps = 1e-6;
    let mut log_sum = vec![0.0f64; normalized.cols];
    for &r in members {
        for (s, &v) in log_sum.iter_mut().zip(normalized.row(r)) {
            *s += v.max(eps).ln();
        }
    }
    log_sum
        .into_iter()
        .map(|s| (s / members.len() as f64).exp())
        .collect()
}

/// Configuration indices of `profile` in descending-value order.
fn ranked_configs(profile: &[f64]) -> Vec<usize> {
    let mut order: Vec<usize> = (0..profile.len()).collect();
    order.sort_by(|&a, &b| profile[b].partial_cmp(&profile[a]).unwrap());
    order
}

/// Push the first entry of `ranked` not already in `picks`.
fn push_unique(picks: &mut Vec<usize>, ranked: Vec<usize>) {
    for c in ranked {
        if !picks.contains(&c) {
            picks.push(c);
            return;
        }
    }
}

/// Pad an under-full selection with globally strong configurations (keeps
/// every method returning exactly k distinct kernels, e.g. when HDBSCAN
/// finds fewer clusters than requested).
fn fill_to_k(picks: &mut Vec<usize>, train: &PerfDataset, k: usize) {
    if picks.len() >= k {
        return;
    }
    let normalized = train.normalized(Normalization::Standard);
    let all: Vec<usize> = (0..normalized.rows).collect();
    let gm = geomean_profile(&normalized, &all);
    for c in ranked_configs(&gm) {
        if picks.len() >= k {
            break;
        }
        if !picks.contains(&c) {
            picks.push(c);
        }
    }
}

/// Convenience: the single globally-best configuration (what a CLBlast-style
/// tuner would deploy — used as the `single-config` comparator backend).
pub fn single_best(train: &PerfDataset) -> usize {
    let normalized = train.normalized(Normalization::Standard);
    let all: Vec<usize> = (0..normalized.rows).collect();
    argmax(&geomean_profile(&normalized, &all))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::{benchmark_shapes, GemmShape};
    use crate::devsim::{generate_dataset, profile_by_name};
    use crate::util::Rng;

    fn small_dataset() -> PerfDataset {
        let shapes: Vec<GemmShape> =
            benchmark_shapes().into_iter().step_by(7).collect();
        generate_dataset(profile_by_name("r9-nano").unwrap(), &shapes)
    }

    #[test]
    fn all_methods_return_k_distinct_valid() {
        let ds = small_dataset();
        for method in ALL_METHODS {
            for k in [4usize, 8] {
                let picks = select(method, &ds, Normalization::Standard, k, 1);
                assert_eq!(picks.len(), k, "{method:?} k={k}");
                let set: std::collections::HashSet<_> = picks.iter().collect();
                assert_eq!(set.len(), k, "{method:?} duplicates");
                assert!(picks.iter().all(|&c| c < NUM_CONFIGS));
            }
        }
    }

    #[test]
    fn property_random_datasets_yield_valid_selections() {
        // Property-style sweep: random synthetic datasets, every method and
        // normalization must produce k distinct in-range configs.
        let mut rng = Rng::new(42);
        for trial in 0..3 {
            let n = 20 + 5 * trial;
            let shapes: Vec<GemmShape> = (0..n)
                .map(|i| GemmShape::new(8 << (i % 6), 16 << (i % 5), 8 << ((i + 2) % 6), 1 + (i % 4)))
                .collect();
            let rows: Vec<Vec<f64>> = (0..n)
                .map(|_| (0..NUM_CONFIGS).map(|_| 1.0 + rng.uniform() * 999.0).collect())
                .collect();
            let ds = PerfDataset::new("prop", shapes, Matrix::from_rows(&rows));
            for method in ALL_METHODS {
                for norm in crate::dataset::ALL_NORMALIZATIONS {
                    let picks = select(method, &ds, norm, 5, trial as u64);
                    assert_eq!(picks.len(), 5, "{method:?}/{norm:?}");
                    let set: std::collections::HashSet<_> = picks.iter().collect();
                    assert_eq!(set.len(), 5);
                }
            }
        }
    }

    #[test]
    fn top_n_matches_winner_counts() {
        let ds = small_dataset();
        let picks = select(Method::TopN, &ds, Normalization::Standard, 4, 0);
        let counts = ds.winner_counts();
        // Every pick must have a count >= the best unpicked count (allowing
        // tie-break reordering).
        let min_picked = picks.iter().map(|&c| counts[c]).min().unwrap();
        let max_unpicked = (0..NUM_CONFIGS)
            .filter(|c| !picks.contains(c))
            .map(|c| counts[c])
            .max()
            .unwrap();
        assert!(
            min_picked >= max_unpicked,
            "TopN picked count {min_picked} < unpicked {max_unpicked}"
        );
    }

    #[test]
    fn single_best_is_strong() {
        let ds = small_dataset();
        let best = single_best(&ds);
        // The single best config must beat a random config on geomean.
        let norm = ds.normalized(Normalization::Standard);
        let all: Vec<usize> = (0..norm.rows).collect();
        let gm = geomean_profile(&norm, &all);
        assert!(gm[best] >= gm[17]);
        assert!(gm[best] >= gm[333]);
    }

    #[test]
    fn ml_methods_beat_topn_at_small_k() {
        // The paper's headline (§4.3): clustering beats Top-N for small k.
        let shapes: Vec<GemmShape> =
            benchmark_shapes().into_iter().step_by(2).collect();
        let ds = generate_dataset(profile_by_name("r9-nano").unwrap(), &shapes);
        let split = ds.split(0.8, 7);
        let train = ds.subset(&split.train);
        let test = ds.subset(&split.test);
        let topn = select(Method::TopN, &train, Normalization::Standard, 6, 1);
        let km = select(Method::KMeans, &train, Normalization::Standard, 6, 1);
        let p_topn = achievable_percent(&test, &topn);
        let p_km = achievable_percent(&test, &km);
        assert!(
            p_km > p_topn - 2.0,
            "KMeans {p_km:.1}% should not trail TopN {p_topn:.1}% badly"
        );
    }
}
