//! Evaluation of a deployed-kernel selection (paper §4.3): what percentage
//! of the optimal performance survives when only the selected kernels are
//! available, aggregated as the geometric mean over the test size sets.

use crate::dataset::PerfDataset;
use crate::linalg::stats::geomean;

/// Percentage (0..100) of optimal performance achievable on `test` when an
/// oracle picks the best of `selected` per size set — the paper's
/// "maximum achievable performance" for a deployment.
pub fn achievable_percent(test: &PerfDataset, selected: &[usize]) -> f64 {
    assert!(!selected.is_empty());
    let rels: Vec<f64> = (0..test.n_shapes())
        .map(|r| {
            selected
                .iter()
                .map(|&c| test.relative(r, c))
                .fold(0.0f64, f64::max)
        })
        .collect();
    geomean(&rels) * 100.0
}

/// Percentage of optimal performance when a *classifier's* per-shape config
/// choice (an index into the full config space) is used instead of the
/// oracle.
pub fn achieved_percent(test: &PerfDataset, choices: &[usize]) -> f64 {
    assert_eq!(choices.len(), test.n_shapes());
    let rels: Vec<f64> = (0..test.n_shapes())
        .map(|r| test.relative(r, choices[r]))
        .collect();
    geomean(&rels) * 100.0
}

/// Full selection evaluation row: method picks on train, achievable on test.
pub fn evaluate_selection(
    train: &PerfDataset,
    test: &PerfDataset,
    method: super::Method,
    norm: crate::dataset::Normalization,
    k: usize,
    seed: u64,
) -> (Vec<usize>, f64) {
    let picks = super::select(method, train, norm, k, seed);
    let pct = achievable_percent(test, &picks);
    (picks, pct)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::{GemmShape, PerfDataset, NUM_CONFIGS};
    use crate::linalg::Matrix;

    fn two_regime_dataset() -> PerfDataset {
        // Rows 0..5 are fastest on config 0, rows 5..10 on config 1; all
        // other configs are 10x slower.
        let shapes: Vec<GemmShape> =
            (0..10).map(|i| GemmShape::new(16 + i, 32, 16, 1)).collect();
        let rows: Vec<Vec<f64>> = (0..10)
            .map(|i| {
                (0..NUM_CONFIGS)
                    .map(|c| {
                        if (i < 5 && c == 0) || (i >= 5 && c == 1) {
                            100.0
                        } else {
                            10.0
                        }
                    })
                    .collect()
            })
            .collect();
        PerfDataset::new("2regime", shapes, Matrix::from_rows(&rows))
    }

    #[test]
    fn oracle_with_both_winners_is_100() {
        let ds = two_regime_dataset();
        assert!((achievable_percent(&ds, &[0, 1]) - 100.0).abs() < 1e-9);
    }

    #[test]
    fn single_winner_costs_half_the_rows() {
        let ds = two_regime_dataset();
        let pct = achievable_percent(&ds, &[0]);
        // Half the rows at 100%, half at 10% -> geomean = sqrt(0.1) ~ 31.6%.
        assert!((pct - 31.62).abs() < 0.5, "pct={pct}");
    }

    #[test]
    fn achieved_tracks_choices() {
        let ds = two_regime_dataset();
        let perfect: Vec<usize> = (0..10).map(|i| if i < 5 { 0 } else { 1 }).collect();
        assert!((achieved_percent(&ds, &perfect) - 100.0).abs() < 1e-9);
        let inverted: Vec<usize> = (0..10).map(|i| if i < 5 { 1 } else { 0 }).collect();
        assert!((achieved_percent(&ds, &inverted) - 10.0).abs() < 1e-9);
    }

    #[test]
    fn more_kernels_never_hurt_oracle() {
        let ds = two_regime_dataset();
        let p1 = achievable_percent(&ds, &[0]);
        let p2 = achievable_percent(&ds, &[0, 1]);
        let p3 = achievable_percent(&ds, &[0, 1, 2]);
        assert!(p2 >= p1);
        assert!(p3 >= p2);
    }
}
