//! # kernelsel
//!
//! A reproduction of *"Performance portability through machine learning
//! guided kernel selection in SYCL libraries"* (Lawson, 2020) as a
//! three-layer Rust + JAX/Pallas + PJRT stack:
//!
//! * **Layer 1** (`python/compile/kernels/`): the paper's parameterized GEMM
//!   as a Pallas kernel — 640 configurations of micro-tile and work-group
//!   parameters, AOT-lowered to HLO-text artifacts.
//! * **Layer 2** (`python/compile/model.py`): JAX compute graphs (VGG16 via
//!   im2col) calling the kernel; lowered once at build time.
//! * **Layer 3** (this crate): everything at runtime — the benchmark data
//!   pipeline, the unsupervised kernel-subset selection, the runtime
//!   classifier, the PJRT executor, and the serving coordinator.

pub mod classify;
pub mod coordinator;
pub mod dataset;
pub mod devsim;
pub mod experiments;
pub mod linalg;
pub mod ml;
pub mod runtime;
pub mod selection;
pub mod util;
