//! # kernelsel
//!
//! A reproduction of *"Performance portability through machine learning
//! guided kernel selection in SYCL libraries"* (Lawson, 2020) as a
//! four-layer Rust + JAX/Pallas stack:
//!
//! * **Layer 1 — kernels** (`python/compile/kernels/`): the paper's
//!   parameterized GEMM as a Pallas kernel — 640 configurations of
//!   micro-tile and work-group parameters, AOT-lowered to HLO-text
//!   artifacts.
//! * **Layer 2 — graphs** (`python/compile/model.py`): JAX compute graphs
//!   (VGG16 via im2col) calling the kernel; lowered once at build time.
//! * **Layer 3 — engine backends** ([`engine`]): the [`engine::Backend`]
//!   trait over load/compile/execute of an AOT artifact, with the
//!   pure-Rust devsim-driven [`engine::SimBackend`] always available and
//!   the native PJRT backend behind the `pjrt` cargo feature
//!   ([`runtime`] holds the manifest and the PJRT wrapper).
//! * **Layer 4 — coordinator shards** ([`coordinator`]): the serving side —
//!   benchmark data pipeline, unsupervised kernel-subset selection, the
//!   runtime classifier with its memoized hot path, and a load-aware,
//!   work-stealing executor pool with per-shard batching and metrics.
//!
//! Cutting across layers 3 and 4, the [`tuning`] subsystem closes the
//! loop at runtime: shards feed measured execution times into a telemetry
//! sink, a drift detector compares them against the devsim predictions,
//! and a background retuner re-runs selection + classification on the
//! measured data and hot-swaps the selector without pausing traffic.

// Every public item must carry rustdoc. All modules are fully documented
// and gated — CI promotes rustdoc warnings to errors (`cargo doc` with
// `RUSTDOCFLAGS: -D warnings`), so a new undocumented public item or a
// broken intra-doc link fails the build.
#![warn(missing_docs)]

pub mod classify;
pub mod coordinator;
pub mod dataset;
pub mod devsim;
pub mod engine;
pub mod experiments;
pub mod linalg;
pub mod ml;
pub mod runtime;
pub mod selection;
pub mod tuning;
pub mod util;
