//! HDBSCAN density-based clustering (paper §4.1.4).
//!
//! Full pipeline from Campello/Moulavi/Sander (2013) and McInnes/Healy
//! (2017): core distances -> mutual-reachability graph -> Prim MST ->
//! single-linkage dendrogram -> condensed tree (min_cluster_size) ->
//! excess-of-mass cluster extraction with stability scores.  Noise points
//! get the label `NOISE` (-1 equivalent).
//!
//! HDBSCAN has no direct "number of clusters" parameter; like the paper we
//! provide a hyperparameter sweep (`sweep_for_k`) that searches
//! (min_cluster_size, min_samples) for a setting yielding the target count.

use crate::linalg::{euclidean, Matrix};

/// Label assigned to points that belong to no cluster.
pub const NOISE: isize = -1;

/// HDBSCAN hyperparameters.
#[derive(Clone, Debug)]
pub struct HdbscanParams {
    /// Smallest group that may survive condensation as a cluster.
    pub min_cluster_size: usize,
    /// Neighbor count defining the core distance (density smoothing).
    pub min_samples: usize,
}

impl HdbscanParams {
    /// Bundle the two hyperparameters.
    pub fn new(min_cluster_size: usize, min_samples: usize) -> Self {
        HdbscanParams { min_cluster_size, min_samples }
    }
}

/// HDBSCAN fit result.
#[derive(Clone, Debug)]
pub struct Hdbscan {
    /// Per-point labels: 0..n_clusters, or NOISE.
    pub labels: Vec<isize>,
    /// Number of clusters extracted (noise excluded).
    pub n_clusters: usize,
    /// Stability score per extracted cluster.
    pub stabilities: Vec<f64>,
}

// ---------------------------------------------------------------------------
// Dendrogram construction.
// ---------------------------------------------------------------------------

#[derive(Clone, Copy, Debug)]
struct Merge {
    left: usize,  // node id (leaf < n, internal >= n)
    right: usize,
    dist: f64,
    size: usize,
}

struct UnionFind {
    parent: Vec<usize>,
    /// Current dendrogram node id for each set root.
    node: Vec<usize>,
}

impl UnionFind {
    fn new(n: usize) -> Self {
        UnionFind { parent: (0..n).collect(), node: (0..n).collect() }
    }

    fn find(&mut self, mut x: usize) -> usize {
        while self.parent[x] != x {
            self.parent[x] = self.parent[self.parent[x]];
            x = self.parent[x];
        }
        x
    }
}

fn core_distances(x: &Matrix, min_samples: usize) -> Vec<f64> {
    let n = x.rows;
    let k = min_samples.max(1).min(n.saturating_sub(1));
    (0..n)
        .map(|i| {
            let mut d: Vec<f64> =
                (0..n).filter(|&j| j != i).map(|j| euclidean(x.row(i), x.row(j))).collect();
            if d.is_empty() {
                return 0.0;
            }
            d.sort_by(|a, b| a.partial_cmp(b).unwrap());
            d[k - 1]
        })
        .collect()
}

/// Prim's MST over the implicit complete mutual-reachability graph. O(n^2).
fn mst_mutual_reachability(x: &Matrix, core: &[f64]) -> Vec<(usize, usize, f64)> {
    let n = x.rows;
    let mut in_tree = vec![false; n];
    let mut best_dist = vec![f64::INFINITY; n];
    let mut best_from = vec![0usize; n];
    let mut edges = Vec::with_capacity(n.saturating_sub(1));
    in_tree[0] = true;
    let mut latest = 0usize;
    for _ in 1..n {
        // Relax edges from the latest tree vertex.
        for j in 0..n {
            if in_tree[j] {
                continue;
            }
            let d = euclidean(x.row(latest), x.row(j))
                .max(core[latest])
                .max(core[j]);
            if d < best_dist[j] {
                best_dist[j] = d;
                best_from[j] = latest;
            }
        }
        // Pick the nearest non-tree vertex.
        let mut pick = usize::MAX;
        let mut pick_d = f64::INFINITY;
        for j in 0..n {
            if !in_tree[j] && best_dist[j] < pick_d {
                pick_d = best_dist[j];
                pick = j;
            }
        }
        in_tree[pick] = true;
        edges.push((best_from[pick], pick, pick_d));
        latest = pick;
    }
    edges
}

fn single_linkage(mut edges: Vec<(usize, usize, f64)>, n: usize) -> Vec<Merge> {
    edges.sort_by(|a, b| a.2.partial_cmp(&b.2).unwrap());
    let mut uf = UnionFind::new(n);
    let mut merges = Vec::with_capacity(n.saturating_sub(1));
    let mut sizes = vec![1usize; n]; // indexed by node id
    sizes.reserve(2 * n);
    for (a, b, d) in edges {
        let ra = uf.find(a);
        let rb = uf.find(b);
        let (na, nb) = (uf.node[ra], uf.node[rb]);
        let new_node = n + merges.len();
        let size = sizes[na] + sizes[nb];
        merges.push(Merge { left: na, right: nb, dist: d, size });
        sizes.push(size);
        // Union.
        uf.parent[ra] = rb;
        uf.node[rb] = new_node;
    }
    merges
}

// ---------------------------------------------------------------------------
// Condensed tree.
// ---------------------------------------------------------------------------

#[derive(Clone, Debug)]
struct CondensedCluster {
    parent: Option<usize>,
    lambda_birth: f64,
    /// (point, lambda at which the point exits this cluster).
    points: Vec<(usize, f64)>,
    children: Vec<usize>,
    stability: f64,
}

fn lambda_of(dist: f64) -> f64 {
    if dist > 0.0 {
        1.0 / dist
    } else {
        f64::INFINITY
    }
}

/// Condense a dendrogram: clusters smaller than `mcs` dissolve into their
/// parent as per-point fall-outs at the lambda where they detach — the
/// reference `condense_tree` algorithm of the hdbscan library.
fn condense(merges: &[Merge], n: usize, mcs: usize) -> Vec<CondensedCluster> {
    let node_size = |id: usize| if id < n { 1 } else { merges[id - n].size };
    let mut clusters: Vec<CondensedCluster> = vec![CondensedCluster {
        parent: None,
        lambda_birth: 0.0,
        points: Vec::new(),
        children: Vec::new(),
        stability: 0.0,
    }];
    if merges.is_empty() {
        for p in 0..n {
            clusters[0].points.push((p, f64::INFINITY));
        }
        return clusters;
    }

    enum Item {
        /// Walk a dendrogram node that still carries cluster `cl`.
        Walk { node: usize, cl: usize },
        /// Everything under `node` fell out of `cl` at `lam`.
        FallOut { node: usize, cl: usize, lam: f64 },
    }

    let root = n + merges.len() - 1;
    let mut stack = vec![Item::Walk { node: root, cl: 0 }];
    while let Some(item) = stack.pop() {
        match item {
            Item::FallOut { node, cl, lam } => {
                if node < n {
                    clusters[cl].points.push((node, lam));
                } else {
                    let m = merges[node - n];
                    stack.push(Item::FallOut { node: m.left, cl, lam });
                    stack.push(Item::FallOut { node: m.right, cl, lam });
                }
            }
            Item::Walk { node, cl } => {
                if node < n {
                    // Single-point "cluster" (only at a degenerate root).
                    clusters[cl].points.push((node, f64::INFINITY));
                    continue;
                }
                let m = merges[node - n];
                let lam = lambda_of(m.dist);
                let (ls, rs) = (node_size(m.left), node_size(m.right));
                if ls >= mcs && rs >= mcs {
                    // True split: two new condensed clusters born here.
                    for child in [m.left, m.right] {
                        let id = clusters.len();
                        clusters.push(CondensedCluster {
                            parent: Some(cl),
                            lambda_birth: lam,
                            points: Vec::new(),
                            children: Vec::new(),
                            stability: 0.0,
                        });
                        clusters[cl].children.push(id);
                        stack.push(Item::Walk { node: child, cl: id });
                    }
                } else if ls >= mcs {
                    // Right side dissolves at this lambda; the cluster
                    // continues through the left side.
                    stack.push(Item::FallOut { node: m.right, cl, lam });
                    stack.push(Item::Walk { node: m.left, cl });
                } else if rs >= mcs {
                    stack.push(Item::FallOut { node: m.left, cl, lam });
                    stack.push(Item::Walk { node: m.right, cl });
                } else {
                    // Both sides too small: the cluster evaporates here.
                    stack.push(Item::FallOut { node: m.left, cl, lam });
                    stack.push(Item::FallOut { node: m.right, cl, lam });
                }
            }
        }
    }

    // Stability = sum over point exits of (lambda_exit - lambda_birth) plus,
    // for each child cluster, its point count times (lambda_child_birth -
    // lambda_birth): points passing into children exit the parent there.
    let mut subtree_points = vec![0usize; clusters.len()];
    for i in (0..clusters.len()).rev() {
        subtree_points[i] = clusters[i].points.len()
            + clusters[i]
                .children
                .iter()
                .map(|&c| subtree_points[c])
                .sum::<usize>();
    }
    for i in 0..clusters.len() {
        let birth = clusters[i].lambda_birth;
        let max_finite = clusters[i]
            .points
            .iter()
            .map(|&(_, l)| l)
            .filter(|l| l.is_finite())
            .fold(0.0f64, f64::max)
            .max(birth);
        let mut stab: f64 = clusters[i]
            .points
            .iter()
            .map(|&(_, l)| {
                let l = if l.is_finite() { l } else { max_finite };
                (l - birth).max(0.0)
            })
            .sum();
        for &c in clusters[i].children.clone().iter() {
            stab += subtree_points[c] as f64 * (clusters[c].lambda_birth - birth).max(0.0);
        }
        clusters[i].stability = stab;
    }
    clusters
}

/// Excess-of-mass cluster extraction.
fn extract_eom(clusters: &[CondensedCluster]) -> Vec<usize> {
    let n = clusters.len();
    // Children lists let us process bottom-up by index order (children are
    // always created after parents, so reverse index order is topological).
    let mut subtree_stability = vec![0.0f64; n];
    let mut selected = vec![false; n];
    for i in (0..n).rev() {
        let child_sum: f64 = clusters[i]
            .children
            .iter()
            .map(|&c| subtree_stability[c])
            .sum();
        if clusters[i].children.is_empty() {
            subtree_stability[i] = clusters[i].stability;
            selected[i] = true;
        } else if clusters[i].stability > child_sum && clusters[i].parent.is_some() {
            subtree_stability[i] = clusters[i].stability;
            selected[i] = true;
        } else {
            subtree_stability[i] = child_sum;
        }
    }
    // Never select the root (matches allow_single_cluster=False).
    selected[0] = false;
    // Keep only the highest selected cluster on each root-to-leaf path.
    let mut result = Vec::new();
    let mut stack = vec![0usize];
    while let Some(i) = stack.pop() {
        if selected[i] && i != 0 {
            result.push(i);
        } else {
            stack.extend(clusters[i].children.iter().copied());
        }
    }
    result.sort_unstable();
    result
}

/// Run HDBSCAN on rows of `x`.
pub fn hdbscan(x: &Matrix, params: &HdbscanParams) -> Hdbscan {
    let n = x.rows;
    if n == 0 {
        return Hdbscan { labels: vec![], n_clusters: 0, stabilities: vec![] };
    }
    let mcs = params.min_cluster_size.max(2);
    let core = core_distances(x, params.min_samples);
    let mst = mst_mutual_reachability(x, &core);
    let merges = single_linkage(mst, n);
    let condensed = condense(&merges, n, mcs);
    let chosen = extract_eom(&condensed);

    let mut labels = vec![NOISE; n];
    let mut stabilities = Vec::with_capacity(chosen.len());
    for (out_label, &cl) in chosen.iter().enumerate() {
        stabilities.push(condensed[cl].stability);
        // All points in the subtree rooted at `cl` belong to the cluster.
        let mut stack = vec![cl];
        while let Some(c) = stack.pop() {
            for &(p, _) in &condensed[c].points {
                labels[p] = out_label as isize;
            }
            stack.extend(condensed[c].children.iter().copied());
        }
    }
    Hdbscan { labels, n_clusters: chosen.len(), stabilities }
}

/// Sweep (min_cluster_size, min_samples) for a setting that yields exactly
/// `k` clusters; falls back to the closest count (paper §4.1.4: "we compute
/// the numbers of clusters for a sweep of the hyperparameters").
pub fn sweep_for_k(x: &Matrix, k: usize) -> (Hdbscan, HdbscanParams) {
    let n = x.rows;
    let mut best: Option<(Hdbscan, HdbscanParams, usize)> = None;
    let max_mcs = (n / 2).max(3);
    let mut mcs = 2usize;
    while mcs <= max_mcs {
        for ms in [1usize, 2, 3, 5, 8] {
            if ms >= n {
                continue;
            }
            let params = HdbscanParams::new(mcs, ms);
            let fit = hdbscan(x, &params);
            let err = fit.n_clusters.abs_diff(k);
            let better = match &best {
                None => true,
                Some((bf, _, berr)) => {
                    err < *berr
                        || (err == *berr
                            && count_noise(&fit.labels) < count_noise(&bf.labels))
                }
            };
            if better {
                best = Some((fit, params, err));
            }
        }
        mcs += 1 + mcs / 4;
    }
    let (fit, params, _) = best.expect("sweep_for_k: empty sweep");
    (fit, params)
}

fn count_noise(labels: &[isize]) -> usize {
    labels.iter().filter(|&&l| l == NOISE).count()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn blobs(centers: &[(f64, f64)], per: usize, spread: f64, seed: u64) -> (Matrix, Vec<usize>) {
        let mut rng = Rng::new(seed);
        let mut rows = Vec::new();
        let mut truth = Vec::new();
        for (i, (cx, cy)) in centers.iter().enumerate() {
            for _ in 0..per {
                rows.push(vec![cx + rng.normal() * spread, cy + rng.normal() * spread]);
                truth.push(i);
            }
        }
        (Matrix::from_rows(&rows), truth)
    }

    #[test]
    fn finds_three_blobs() {
        let (x, truth) = blobs(&[(0.0, 0.0), (10.0, 0.0), (0.0, 10.0)], 30, 0.4, 1);
        let fit = hdbscan(&x, &HdbscanParams::new(5, 3));
        assert_eq!(fit.n_clusters, 3, "labels: {:?}", fit.labels);
        // Purity among non-noise points.
        for c in 0..3 {
            let members: Vec<usize> = (0..x.rows)
                .filter(|&i| fit.labels[i] == c as isize)
                .collect();
            assert!(members.len() >= 25, "cluster {c} too small");
            let t = truth[members[0]];
            assert!(members.iter().all(|&m| truth[m] == t));
        }
    }

    #[test]
    fn marks_outliers_as_noise() {
        let (mut x, _) = blobs(&[(0.0, 0.0), (10.0, 0.0)], 30, 0.3, 2);
        // Add far-away isolated points.
        x = Matrix::from_rows(
            &x.data
                .chunks(2)
                .map(|c| c.to_vec())
                .chain([vec![100.0, 100.0], vec![-80.0, 50.0]])
                .collect::<Vec<_>>(),
        );
        let fit = hdbscan(&x, &HdbscanParams::new(5, 3));
        assert_eq!(fit.n_clusters, 2);
        assert_eq!(fit.labels[x.rows - 1], NOISE);
        assert_eq!(fit.labels[x.rows - 2], NOISE);
    }

    #[test]
    fn density_difference_detected() {
        // A tight blob inside a diffuse background should still split out.
        let (a, _) = blobs(&[(0.0, 0.0)], 40, 0.2, 3);
        let (b, _) = blobs(&[(6.0, 0.0)], 40, 1.2, 4);
        let rows: Vec<Vec<f64>> = a
            .data
            .chunks(2)
            .chain(b.data.chunks(2))
            .map(|c| c.to_vec())
            .collect();
        let x = Matrix::from_rows(&rows);
        let fit = hdbscan(&x, &HdbscanParams::new(8, 4));
        assert!(fit.n_clusters >= 2, "got {} clusters", fit.n_clusters);
    }

    #[test]
    fn stabilities_positive() {
        let (x, _) = blobs(&[(0.0, 0.0), (10.0, 0.0)], 25, 0.3, 5);
        let fit = hdbscan(&x, &HdbscanParams::new(5, 3));
        assert_eq!(fit.stabilities.len(), fit.n_clusters);
        assert!(fit.stabilities.iter().all(|&s| s > 0.0));
    }

    #[test]
    fn core_distance_monotone_in_min_samples() {
        let (x, _) = blobs(&[(0.0, 0.0)], 20, 0.5, 6);
        let c2 = core_distances(&x, 2);
        let c5 = core_distances(&x, 5);
        for i in 0..x.rows {
            assert!(c5[i] >= c2[i] - 1e-12);
        }
    }

    #[test]
    fn mst_has_n_minus_1_edges_and_spans() {
        let (x, _) = blobs(&[(0.0, 0.0), (5.0, 5.0)], 15, 0.4, 7);
        let core = core_distances(&x, 3);
        let mst = mst_mutual_reachability(&x, &core);
        assert_eq!(mst.len(), x.rows - 1);
        // Spanning: union-find all edges -> single component.
        let mut uf = UnionFind::new(x.rows);
        for &(a, b, _) in &mst {
            let (ra, rb) = (uf.find(a), uf.find(b));
            uf.parent[ra] = rb;
        }
        let root = uf.find(0);
        for i in 1..x.rows {
            assert_eq!(uf.find(i), root);
        }
    }

    #[test]
    fn mst_weight_not_above_random_spanning_tree() {
        let (x, _) = blobs(&[(0.0, 0.0)], 25, 1.0, 8);
        let core = core_distances(&x, 3);
        let mst_w: f64 = mst_mutual_reachability(&x, &core)
            .iter()
            .map(|e| e.2)
            .sum();
        // Star tree rooted at 0 is a valid spanning tree.
        let star_w: f64 = (1..x.rows)
            .map(|j| {
                euclidean(x.row(0), x.row(j)).max(core[0]).max(core[j])
            })
            .sum();
        assert!(mst_w <= star_w + 1e-9);
    }

    #[test]
    fn sweep_hits_target_k() {
        let (x, _) = blobs(&[(0.0, 0.0), (10.0, 0.0), (0.0, 10.0), (10.0, 10.0)], 25, 0.4, 9);
        let (fit, params) = sweep_for_k(&x, 4);
        assert_eq!(fit.n_clusters, 4, "params {params:?}");
    }

    #[test]
    fn single_point_dataset() {
        let x = Matrix::from_rows(&[vec![1.0, 2.0]]);
        let fit = hdbscan(&x, &HdbscanParams::new(2, 1));
        assert_eq!(fit.labels.len(), 1);
        assert_eq!(fit.n_clusters, 0);
    }
}
