//! Random forest classifier (paper §5.1 comparator): bagged CART trees with
//! per-split feature subsampling and majority vote.

use crate::linalg::Matrix;
use crate::ml::decision_tree::{TreeClassifier, TreeParams};
use crate::util::Rng;

/// Random-forest hyperparameters.
#[derive(Clone, Debug)]
pub struct ForestParams {
    /// Trees in the ensemble.
    pub n_trees: usize,
    /// Per-tree depth cap; `None` = unlimited.
    pub max_depth: Option<usize>,
    /// Minimum samples per leaf in each tree.
    pub min_samples_leaf: usize,
    /// Features per split; None = floor(sqrt(d)).
    pub max_features: Option<usize>,
    /// Base seed; each tree's bootstrap and splits fork from it.
    pub seed: u64,
}

impl Default for ForestParams {
    fn default() -> Self {
        ForestParams {
            n_trees: 50,
            max_depth: None,
            min_samples_leaf: 1,
            max_features: None,
            seed: 0,
        }
    }
}

/// Bagged ensemble of Gini CART trees.
#[derive(Clone, Debug)]
pub struct RandomForest {
    /// The fitted trees, each on its own bootstrap sample.
    pub trees: Vec<TreeClassifier>,
    /// Number of distinct class labels seen in training.
    pub n_classes: usize,
}

impl RandomForest {
    /// Fit `n_trees` trees on bootstrap resamples of `(x, y)`.
    pub fn fit(x: &Matrix, y: &[usize], params: &ForestParams) -> RandomForest {
        assert_eq!(x.rows, y.len());
        let n_classes = y.iter().max().copied().unwrap_or(0) + 1;
        let max_features = params
            .max_features
            .unwrap_or_else(|| (x.cols as f64).sqrt().floor().max(1.0) as usize);
        let mut rng = Rng::new(params.seed);
        let mut trees = Vec::with_capacity(params.n_trees);
        for t in 0..params.n_trees {
            let mut tree_rng = rng.fork(t as u64 + 1);
            // Bootstrap sample.
            let idx: Vec<usize> = (0..x.rows).map(|_| tree_rng.below(x.rows)).collect();
            let bx = Matrix::from_rows(&idx.iter().map(|&i| x.row(i).to_vec()).collect::<Vec<_>>());
            let by: Vec<usize> = idx.iter().map(|&i| y[i]).collect();
            let tp = TreeParams {
                max_depth: params.max_depth,
                min_samples_leaf: params.min_samples_leaf,
                min_samples_split: 2,
                max_leaves: None,
                max_features: Some(max_features),
                seed: tree_rng.next_u64(),
            };
            trees.push(TreeClassifier::fit(&bx, &by, &tp));
        }
        RandomForest { trees, n_classes }
    }

    /// Majority vote across the ensemble.
    pub fn predict(&self, row: &[f64]) -> usize {
        let mut votes = vec![0usize; self.n_classes];
        for tree in &self.trees {
            let p = tree.predict(row);
            if p < votes.len() {
                votes[p] += 1;
            }
        }
        votes
            .iter()
            .enumerate()
            .max_by_key(|&(_, &v)| v)
            .map(|(i, _)| i)
            .unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn noisy_blobs(seed: u64) -> (Matrix, Vec<usize>) {
        let mut rng = Rng::new(seed);
        let mut rows = Vec::new();
        let mut y = Vec::new();
        for (cls, (cx, cy)) in [(0.0, 0.0), (3.0, 3.0), (0.0, 5.0)].iter().enumerate() {
            for _ in 0..30 {
                rows.push(vec![
                    cx + rng.normal() * 0.8,
                    cy + rng.normal() * 0.8,
                    rng.normal(), // pure-noise feature
                ]);
                y.push(cls);
            }
        }
        (Matrix::from_rows(&rows), y)
    }

    #[test]
    fn classifies_noisy_blobs() {
        let (x, y) = noisy_blobs(1);
        let rf = RandomForest::fit(&x, &y, &ForestParams { n_trees: 30, ..Default::default() });
        let acc = (0..x.rows).filter(|&i| rf.predict(x.row(i)) == y[i]).count() as f64
            / x.rows as f64;
        assert!(acc > 0.9, "train accuracy {acc}");
    }

    #[test]
    fn deterministic_given_seed() {
        let (x, y) = noisy_blobs(2);
        let p = ForestParams { n_trees: 10, seed: 7, ..Default::default() };
        let a = RandomForest::fit(&x, &y, &p);
        let b = RandomForest::fit(&x, &y, &p);
        for i in 0..x.rows {
            assert_eq!(a.predict(x.row(i)), b.predict(x.row(i)));
        }
    }

    #[test]
    fn trees_differ_across_forest() {
        let (x, y) = noisy_blobs(3);
        let rf = RandomForest::fit(&x, &y, &ForestParams { n_trees: 8, ..Default::default() });
        // At least two trees disagree somewhere (bagging diversity).
        let mut diverse = false;
        'outer: for i in 0..x.rows {
            let p0 = rf.trees[0].predict(x.row(i));
            for t in &rf.trees[1..] {
                if t.predict(x.row(i)) != p0 {
                    diverse = true;
                    break 'outer;
                }
            }
        }
        assert!(diverse, "all trees identical — bagging broken?");
    }

    #[test]
    fn n_classes_tracked() {
        let (x, y) = noisy_blobs(4);
        let rf = RandomForest::fit(&x, &y, &ForestParams { n_trees: 5, ..Default::default() });
        assert_eq!(rf.n_classes, 3);
        assert!(rf.predict(x.row(0)) < 3);
    }
}
