//! Multi-layer perceptron classifier (paper §5.1 comparator).
//!
//! Single hidden ReLU layer + softmax cross-entropy, trained with Adam on
//! mini-batches — mirroring scikit-learn's `MLPClassifier` defaults the
//! paper used (hidden size 100, relu, adam).

use crate::linalg::Matrix;
use crate::util::Rng;

/// MLP hyperparameters (defaults mirror scikit-learn's `MLPClassifier`).
#[derive(Clone, Debug)]
pub struct MlpParams {
    /// Hidden-layer width.
    pub hidden: usize,
    /// Full passes over the training set.
    pub epochs: usize,
    /// Mini-batch size for Adam updates.
    pub batch_size: usize,
    /// Adam learning rate.
    pub lr: f64,
    /// L2 weight-decay coefficient.
    pub l2: f64,
    /// Seed for init and batch shuffling.
    pub seed: u64,
}

impl Default for MlpParams {
    fn default() -> Self {
        MlpParams { hidden: 100, epochs: 200, batch_size: 32, lr: 1e-3, l2: 1e-4, seed: 0 }
    }
}

/// Trained one-hidden-layer perceptron (ReLU + softmax).
#[derive(Clone, Debug)]
pub struct Mlp {
    w1: Matrix, // (d x h)
    b1: Vec<f64>,
    w2: Matrix, // (h x c)
    b2: Vec<f64>,
    /// Number of distinct class labels seen in training.
    pub n_classes: usize,
}

struct Adam {
    m: Vec<f64>,
    v: Vec<f64>,
    t: usize,
}

impl Adam {
    fn new(n: usize) -> Adam {
        Adam { m: vec![0.0; n], v: vec![0.0; n], t: 0 }
    }

    fn step(&mut self, params: &mut [f64], grads: &[f64], lr: f64) {
        const B1: f64 = 0.9;
        const B2: f64 = 0.999;
        const EPS: f64 = 1e-8;
        self.t += 1;
        let bc1 = 1.0 - B1.powi(self.t as i32);
        let bc2 = 1.0 - B2.powi(self.t as i32);
        for i in 0..params.len() {
            self.m[i] = B1 * self.m[i] + (1.0 - B1) * grads[i];
            self.v[i] = B2 * self.v[i] + (1.0 - B2) * grads[i] * grads[i];
            let mhat = self.m[i] / bc1;
            let vhat = self.v[i] / bc2;
            params[i] -= lr * mhat / (vhat.sqrt() + EPS);
        }
    }
}

impl Mlp {
    /// Train with mini-batch Adam on softmax cross-entropy.
    pub fn fit(x: &Matrix, y: &[usize], params: &MlpParams) -> Mlp {
        assert_eq!(x.rows, y.len());
        let d = x.cols;
        let h = params.hidden;
        let c = y.iter().max().copied().unwrap_or(0) + 1;
        let mut rng = Rng::new(params.seed);

        // He init for relu layer, Xavier-ish for the head.
        let mut w1 = Matrix::zeros(d, h);
        for v in &mut w1.data {
            *v = rng.normal() * (2.0 / d as f64).sqrt();
        }
        let mut w2 = Matrix::zeros(h, c);
        for v in &mut w2.data {
            *v = rng.normal() * (1.0 / h as f64).sqrt();
        }
        let mut net = Mlp { w1, b1: vec![0.0; h], w2, b2: vec![0.0; c], n_classes: c };

        let mut opt_w1 = Adam::new(d * h);
        let mut opt_b1 = Adam::new(h);
        let mut opt_w2 = Adam::new(h * c);
        let mut opt_b2 = Adam::new(c);

        let mut order: Vec<usize> = (0..x.rows).collect();
        for _epoch in 0..params.epochs {
            rng.shuffle(&mut order);
            for batch in order.chunks(params.batch_size.max(1)) {
                let bs = batch.len() as f64;
                let mut gw1 = vec![0.0; d * h];
                let mut gb1 = vec![0.0; h];
                let mut gw2 = vec![0.0; h * c];
                let mut gb2 = vec![0.0; c];
                for &i in batch {
                    let row = x.row(i);
                    // Forward.
                    let mut hid = net.b1.clone();
                    for (j, &xj) in row.iter().enumerate() {
                        if xj == 0.0 {
                            continue;
                        }
                        for k in 0..h {
                            hid[k] += xj * net.w1[(j, k)];
                        }
                    }
                    let act: Vec<f64> = hid.iter().map(|&v| v.max(0.0)).collect();
                    let mut logits = net.b2.clone();
                    for k in 0..h {
                        if act[k] == 0.0 {
                            continue;
                        }
                        for o in 0..c {
                            logits[o] += act[k] * net.w2[(k, o)];
                        }
                    }
                    let probs = softmax(&logits);
                    // Backward (cross-entropy).
                    let mut dlogits = probs;
                    dlogits[y[i]] -= 1.0;
                    for o in 0..c {
                        gb2[o] += dlogits[o];
                        for k in 0..h {
                            gw2[k * c + o] += act[k] * dlogits[o];
                        }
                    }
                    let mut dact = vec![0.0; h];
                    for k in 0..h {
                        if hid[k] <= 0.0 {
                            continue; // relu gate
                        }
                        let mut s = 0.0;
                        for o in 0..c {
                            s += dlogits[o] * net.w2[(k, o)];
                        }
                        dact[k] = s;
                        gb1[k] += s;
                    }
                    for (j, &xj) in row.iter().enumerate() {
                        if xj == 0.0 {
                            continue;
                        }
                        for k in 0..h {
                            gw1[j * h + k] += xj * dact[k];
                        }
                    }
                }
                // Average + L2.
                for (g, p) in gw1.iter_mut().zip(&net.w1.data) {
                    *g = *g / bs + params.l2 * p;
                }
                for (g, p) in gw2.iter_mut().zip(&net.w2.data) {
                    *g = *g / bs + params.l2 * p;
                }
                for g in &mut gb1 {
                    *g /= bs;
                }
                for g in &mut gb2 {
                    *g /= bs;
                }
                opt_w1.step(&mut net.w1.data, &gw1, params.lr);
                opt_b1.step(&mut net.b1, &gb1, params.lr);
                opt_w2.step(&mut net.w2.data, &gw2, params.lr);
                opt_b2.step(&mut net.b2, &gb2, params.lr);
            }
        }
        net
    }

    /// Most probable class for one feature row.
    pub fn predict(&self, row: &[f64]) -> usize {
        let probs = self.predict_proba(row);
        crate::linalg::stats::argmax(&probs)
    }

    /// Softmax class probabilities for one feature row.
    pub fn predict_proba(&self, row: &[f64]) -> Vec<f64> {
        let h = self.b1.len();
        let c = self.b2.len();
        let mut hid = self.b1.clone();
        for (j, &xj) in row.iter().enumerate() {
            if xj == 0.0 {
                continue;
            }
            for k in 0..h {
                hid[k] += xj * self.w1[(j, k)];
            }
        }
        for v in &mut hid {
            *v = v.max(0.0);
        }
        let mut logits = self.b2.clone();
        for k in 0..h {
            if hid[k] == 0.0 {
                continue;
            }
            for o in 0..c {
                logits[o] += hid[k] * self.w2[(k, o)];
            }
        }
        softmax(&logits)
    }
}

fn softmax(logits: &[f64]) -> Vec<f64> {
    let mx = logits.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let exps: Vec<f64> = logits.iter().map(|&l| (l - mx).exp()).collect();
    let total: f64 = exps.iter().sum();
    exps.into_iter().map(|e| e / total).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn softmax_normalizes() {
        let p = softmax(&[1.0, 2.0, 3.0]);
        assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!(p[2] > p[1] && p[1] > p[0]);
    }

    #[test]
    fn learns_xor() {
        let mut rows = Vec::new();
        let mut y = Vec::new();
        let mut rng = Rng::new(1);
        for _ in 0..50 {
            for &(a, b) in &[(0.0, 0.0), (0.0, 1.0), (1.0, 0.0), (1.0, 1.0)] {
                rows.push(vec![a + rng.normal() * 0.05, b + rng.normal() * 0.05]);
                y.push(((a as i32) ^ (b as i32)) as usize);
            }
        }
        let x = Matrix::from_rows(&rows);
        let mlp = Mlp::fit(
            &x,
            &y,
            &MlpParams { hidden: 16, epochs: 150, lr: 5e-3, ..Default::default() },
        );
        let acc = (0..x.rows).filter(|&i| mlp.predict(x.row(i)) == y[i]).count() as f64
            / x.rows as f64;
        assert!(acc > 0.95, "XOR accuracy {acc}");
    }

    #[test]
    fn three_class_blobs() {
        let mut rng = Rng::new(2);
        let mut rows = Vec::new();
        let mut y = Vec::new();
        for (cls, (cx, cy)) in [(0.0, 0.0), (4.0, 0.0), (0.0, 4.0)].iter().enumerate() {
            for _ in 0..25 {
                rows.push(vec![cx + rng.normal() * 0.4, cy + rng.normal() * 0.4]);
                y.push(cls);
            }
        }
        let x = Matrix::from_rows(&rows);
        let mlp = Mlp::fit(
            &x,
            &y,
            &MlpParams { hidden: 32, epochs: 100, lr: 3e-3, ..Default::default() },
        );
        let acc = (0..x.rows).filter(|&i| mlp.predict(x.row(i)) == y[i]).count() as f64
            / x.rows as f64;
        assert!(acc > 0.95, "blob accuracy {acc}");
        assert_eq!(mlp.n_classes, 3);
    }

    #[test]
    fn proba_sums_to_one() {
        let x = Matrix::from_rows(&[vec![0.0, 0.0], vec![1.0, 1.0]]);
        let mlp = Mlp::fit(&x, &[0, 1], &MlpParams { hidden: 4, epochs: 10, ..Default::default() });
        let p = mlp.predict_proba(&[0.5, 0.5]);
        assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn deterministic_given_seed() {
        let x = Matrix::from_rows(&[vec![0.0], vec![1.0], vec![2.0], vec![3.0]]);
        let y = [0usize, 0, 1, 1];
        let p = MlpParams { hidden: 8, epochs: 20, seed: 5, ..Default::default() };
        let a = Mlp::fit(&x, &y, &p);
        let b = Mlp::fit(&x, &y, &p);
        assert_eq!(a.predict_proba(&[1.5]), b.predict_proba(&[1.5]));
    }
}
