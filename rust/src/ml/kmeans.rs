//! K-means clustering with k-means++ initialization (paper §4.1.1).

use crate::linalg::{sq_dist, Matrix};
use crate::util::Rng;

/// K-means fit result (the best of the restarts).
#[derive(Clone, Debug)]
pub struct KMeans {
    /// Cluster centers, one per row (k x d).
    pub centroids: Matrix,
    /// Nearest-centroid assignment per training row.
    pub labels: Vec<usize>,
    /// Sum of squared distances to the assigned centroids.
    pub inertia: f64,
    /// Lloyd iterations the winning restart ran.
    pub iterations: usize,
}

/// K-means hyperparameters.
#[derive(Clone, Debug)]
pub struct KMeansParams {
    /// Number of clusters.
    pub k: usize,
    /// Lloyd iteration cap per restart.
    pub max_iter: usize,
    /// Independent k-means++ restarts; the lowest-inertia fit wins.
    pub n_init: usize,
    /// Base RNG seed (each restart forks from it).
    pub seed: u64,
}

impl KMeansParams {
    /// Defaults for `k` clusters: 300 iterations, 8 restarts, seed 0.
    pub fn new(k: usize) -> Self {
        KMeansParams { k, max_iter: 300, n_init: 8, seed: 0 }
    }

    /// Builder-style seed override.
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }
}

/// Fit k-means on rows of `x`; best of `n_init` k-means++ restarts.
pub fn kmeans(x: &Matrix, params: &KMeansParams) -> KMeans {
    assert!(params.k >= 1, "k must be >= 1");
    assert!(
        x.rows >= params.k,
        "k-means: k={} exceeds {} samples",
        params.k,
        x.rows
    );
    let mut base_rng = Rng::new(params.seed);
    let mut best: Option<KMeans> = None;
    for restart in 0..params.n_init.max(1) {
        let mut rng = base_rng.fork(restart as u64 + 1);
        let fit = lloyd(x, params.k, params.max_iter, &mut rng);
        if best.as_ref().map_or(true, |b| fit.inertia < b.inertia) {
            best = Some(fit);
        }
    }
    best.unwrap()
}

fn lloyd(x: &Matrix, k: usize, max_iter: usize, rng: &mut Rng) -> KMeans {
    let mut centroids = plus_plus_init(x, k, rng);
    let mut labels = vec![0usize; x.rows];
    let mut iterations = 0;
    for iter in 0..max_iter {
        iterations = iter + 1;
        // Assignment step.
        let mut changed = false;
        for r in 0..x.rows {
            let row = x.row(r);
            let mut best_c = 0;
            let mut best_d = f64::INFINITY;
            for c in 0..k {
                let d = sq_dist(row, centroids.row(c));
                if d < best_d {
                    best_d = d;
                    best_c = c;
                }
            }
            if labels[r] != best_c {
                labels[r] = best_c;
                changed = true;
            }
        }
        if iter > 0 && !changed {
            break;
        }
        // Update step.
        let mut sums = Matrix::zeros(k, x.cols);
        let mut counts = vec![0usize; k];
        for r in 0..x.rows {
            counts[labels[r]] += 1;
            for (s, &v) in sums.row_mut(labels[r]).iter_mut().zip(x.row(r)) {
                *s += v;
            }
        }
        for c in 0..k {
            if counts[c] == 0 {
                // Re-seed an empty cluster at the point farthest from its
                // centroid assignment.
                let far = farthest_point(x, &centroids, &labels);
                centroids
                    .row_mut(c)
                    .copy_from_slice(x.row(far));
            } else {
                for (cv, sv) in centroids.row_mut(c).iter_mut().zip(sums.row(c)) {
                    *cv = *sv / counts[c] as f64;
                }
            }
        }
    }
    let inertia: f64 = (0..x.rows)
        .map(|r| sq_dist(x.row(r), centroids.row(labels[r])))
        .sum();
    KMeans { centroids, labels, inertia, iterations }
}

fn farthest_point(x: &Matrix, centroids: &Matrix, labels: &[usize]) -> usize {
    let mut best = 0;
    let mut best_d = -1.0;
    for r in 0..x.rows {
        let d = sq_dist(x.row(r), centroids.row(labels[r]));
        if d > best_d {
            best_d = d;
            best = r;
        }
    }
    best
}

/// k-means++ seeding: iteratively pick points with probability proportional
/// to squared distance from the nearest already-chosen center.
fn plus_plus_init(x: &Matrix, k: usize, rng: &mut Rng) -> Matrix {
    let mut centers: Vec<usize> = vec![rng.below(x.rows)];
    let mut d2: Vec<f64> = (0..x.rows)
        .map(|r| sq_dist(x.row(r), x.row(centers[0])))
        .collect();
    while centers.len() < k {
        let total: f64 = d2.iter().sum();
        let next = if total <= 0.0 {
            // All remaining points coincide with a center: pick any unused.
            (0..x.rows).find(|r| !centers.contains(r)).unwrap_or(0)
        } else {
            let mut target = rng.uniform() * total;
            let mut pick = x.rows - 1;
            for (r, &d) in d2.iter().enumerate() {
                target -= d;
                if target <= 0.0 {
                    pick = r;
                    break;
                }
            }
            pick
        };
        centers.push(next);
        for r in 0..x.rows {
            let d = sq_dist(x.row(r), x.row(next));
            if d < d2[r] {
                d2[r] = d;
            }
        }
    }
    Matrix::from_rows(&centers.iter().map(|&c| x.row(c).to_vec()).collect::<Vec<_>>())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn three_blobs(per: usize, seed: u64) -> (Matrix, Vec<usize>) {
        let mut rng = Rng::new(seed);
        let centers = [(0.0, 0.0), (10.0, 0.0), (0.0, 10.0)];
        let mut rows = Vec::new();
        let mut truth = Vec::new();
        for (i, (cx, cy)) in centers.iter().enumerate() {
            for _ in 0..per {
                rows.push(vec![cx + rng.normal() * 0.5, cy + rng.normal() * 0.5]);
                truth.push(i);
            }
        }
        (Matrix::from_rows(&rows), truth)
    }

    #[test]
    fn recovers_separated_blobs() {
        let (x, truth) = three_blobs(40, 1);
        let fit = kmeans(&x, &KMeansParams::new(3).seed(2));
        // Clusters must be pure: map each kmeans label to the majority truth.
        for cluster in 0..3 {
            let members: Vec<usize> = (0..x.rows)
                .filter(|&r| fit.labels[r] == cluster)
                .collect();
            assert_eq!(members.len(), 40, "cluster {cluster} size");
            let t0 = truth[members[0]];
            assert!(members.iter().all(|&m| truth[m] == t0));
        }
    }

    #[test]
    fn assignments_are_nearest_centroid() {
        let (x, _) = three_blobs(30, 3);
        let fit = kmeans(&x, &KMeansParams::new(3).seed(4));
        for r in 0..x.rows {
            let assigned = sq_dist(x.row(r), fit.centroids.row(fit.labels[r]));
            for c in 0..3 {
                assert!(
                    assigned <= sq_dist(x.row(r), fit.centroids.row(c)) + 1e-9
                );
            }
        }
    }

    #[test]
    fn inertia_decreases_with_k() {
        let (x, _) = three_blobs(30, 5);
        let mut prev = f64::INFINITY;
        for k in 1..=5 {
            let fit = kmeans(&x, &KMeansParams::new(k).seed(6));
            assert!(fit.inertia <= prev + 1e-9, "k={k}");
            prev = fit.inertia;
        }
    }

    #[test]
    fn deterministic_for_seed() {
        let (x, _) = three_blobs(20, 7);
        let a = kmeans(&x, &KMeansParams::new(3).seed(8));
        let b = kmeans(&x, &KMeansParams::new(3).seed(8));
        assert_eq!(a.labels, b.labels);
        assert_eq!(a.inertia, b.inertia);
    }

    #[test]
    fn k_equals_n_zero_inertia() {
        let x = Matrix::from_rows(&[vec![0.0, 0.0], vec![1.0, 1.0], vec![2.0, 0.0]]);
        let fit = kmeans(&x, &KMeansParams::new(3).seed(9));
        assert!(fit.inertia < 1e-12);
        let mut l = fit.labels.clone();
        l.sort_unstable();
        assert_eq!(l, vec![0, 1, 2]);
    }

    #[test]
    fn duplicate_points_handled() {
        let rows: Vec<Vec<f64>> = (0..10).map(|_| vec![1.0, 2.0]).collect();
        let x = Matrix::from_rows(&rows);
        let fit = kmeans(&x, &KMeansParams::new(3).seed(10));
        assert_eq!(fit.labels.len(), 10);
        assert!(fit.inertia < 1e-12);
    }

    #[test]
    #[should_panic]
    fn k_larger_than_n_panics() {
        let x = Matrix::from_rows(&[vec![0.0], vec![1.0]]);
        kmeans(&x, &KMeansParams::new(3));
    }
}
