//! CART decision trees (paper §4.1.5 and §5.1).
//!
//! Two specializations share the axis-aligned split machinery:
//!   * `TreeRegressor` — multi-output regression (maps matrix-size features
//!     to full 640-dim performance vectors); used as a *clustering* device
//!     by bounding the number of leaves (§4.1.5).
//!   * `TreeClassifier` — Gini classification (the runtime kernel selector,
//!     §5.1, decision trees A/B/C).

use crate::linalg::Matrix;
use crate::util::Rng;

/// Growth limits shared by both tree kinds (CART stopping rules).
#[derive(Clone, Debug)]
pub struct TreeParams {
    /// Maximum tree depth; `None` = unlimited.
    pub max_depth: Option<usize>,
    /// Minimum training samples a split may leave on either side.
    pub min_samples_leaf: usize,
    /// Minimum samples a node needs before a split is even attempted.
    pub min_samples_split: usize,
    /// Max leaf count (regressor-as-clusterer); None = unlimited.
    pub max_leaves: Option<usize>,
    /// Features considered per split; None = all (set for forests).
    pub max_features: Option<usize>,
    /// Seed for the per-split feature subsampling.
    pub seed: u64,
}

impl Default for TreeParams {
    fn default() -> Self {
        TreeParams {
            max_depth: None,
            min_samples_leaf: 1,
            min_samples_split: 2,
            max_leaves: None,
            max_features: None,
            seed: 0,
        }
    }
}

/// Tree nodes in a flat arena.
#[derive(Clone, Debug)]
pub enum Node {
    /// Internal split: `x[feature] <= threshold` goes left, else right.
    Split {
        /// Feature column the split tests.
        feature: usize,
        /// Split threshold (midpoint between adjacent sorted values).
        threshold: f64,
        /// Arena index of the `<=` subtree.
        left: usize,
        /// Arena index of the `>` subtree.
        right: usize,
    },
    /// Leaf payload index (into `leaf_values` / `leaf_counts`).
    Leaf { payload: usize },
}

// ---------------------------------------------------------------------------
// Split search shared by both tree kinds.
// ---------------------------------------------------------------------------

/// Candidate split of `idx` on `feature` at `threshold` (x <= t goes left).
struct BestSplit {
    feature: usize,
    threshold: f64,
    score: f64, // impurity improvement; higher is better
}

/// Generic split finder: `eval(sorted_idx, split_pos)` scores a candidate
/// partition of the (feature-sorted) index list. Returns the best split.
fn find_best_split<F>(
    x: &Matrix,
    idx: &[usize],
    features: &[usize],
    min_leaf: usize,
    mut eval: F,
) -> Option<BestSplit>
where
    F: FnMut(&[usize], usize) -> f64,
{
    let mut best: Option<BestSplit> = None;
    let mut sorted = idx.to_vec();
    for &f in features {
        sorted.sort_by(|&a, &b| x[(a, f)].partial_cmp(&x[(b, f)]).unwrap());
        for pos in min_leaf..=(sorted.len().saturating_sub(min_leaf)) {
            if pos == 0 || pos == sorted.len() {
                continue;
            }
            let lo = x[(sorted[pos - 1], f)];
            let hi = x[(sorted[pos], f)];
            if hi <= lo {
                continue; // no threshold separates equal values
            }
            let score = eval(&sorted, pos);
            if best.as_ref().map_or(true, |b| score > b.score) {
                best = Some(BestSplit { feature: f, threshold: (lo + hi) / 2.0, score });
            }
        }
    }
    best.filter(|b| b.score > 1e-12)
}

fn feature_subset(n_features: usize, params: &TreeParams, rng: &mut Rng) -> Vec<usize> {
    match params.max_features {
        Some(k) if k < n_features => rng.sample_indices(n_features, k),
        _ => (0..n_features).collect(),
    }
}

// ---------------------------------------------------------------------------
// Multi-output regressor.
// ---------------------------------------------------------------------------

/// Multi-output CART regressor; with `max_leaves` bounded it doubles as
/// the paper's decision-tree clustering device (§4.1.5).
#[derive(Clone, Debug)]
pub struct TreeRegressor {
    /// Flat node arena; index 0 is the root.
    pub nodes: Vec<Node>,
    /// Mean target vector per leaf.
    pub leaf_values: Vec<Vec<f64>>,
    /// Training samples captured by each leaf.
    pub leaf_members: Vec<Vec<usize>>,
    /// Feature count the tree was fitted on.
    pub n_features: usize,
}

struct RegBuildCtx<'a> {
    x: &'a Matrix,
    y: &'a Matrix,
    params: &'a TreeParams,
}

impl TreeRegressor {
    /// Fit on features `x` (n x d) and multi-output targets `y` (n x t).
    pub fn fit(x: &Matrix, y: &Matrix, params: &TreeParams) -> TreeRegressor {
        assert_eq!(x.rows, y.rows, "x/y row mismatch");
        assert!(x.rows > 0, "empty training set");
        let mut tree = TreeRegressor {
            nodes: Vec::new(),
            leaf_values: Vec::new(),
            leaf_members: Vec::new(),
            n_features: x.cols,
        };
        let ctx = RegBuildCtx { x, y, params };
        let mut rng = Rng::new(params.seed);
        let all: Vec<usize> = (0..x.rows).collect();

        if let Some(max_leaves) = params.max_leaves {
            tree.build_best_first(&ctx, all, max_leaves, &mut rng);
        } else {
            let root = tree.build_depth_first(&ctx, all, 0, &mut rng);
            debug_assert_eq!(root, 0);
        }
        tree
    }

    fn make_leaf(&mut self, ctx: &RegBuildCtx, idx: Vec<usize>) -> usize {
        let t = ctx.y.cols;
        let mut mean = vec![0.0; t];
        for &i in &idx {
            for (m, &v) in mean.iter_mut().zip(ctx.y.row(i)) {
                *m += v;
            }
        }
        for m in &mut mean {
            *m /= idx.len() as f64;
        }
        let payload = self.leaf_values.len();
        self.leaf_values.push(mean);
        self.leaf_members.push(idx);
        self.nodes.push(Node::Leaf { payload });
        self.nodes.len() - 1
    }

    fn split_of(
        &self,
        ctx: &RegBuildCtx,
        idx: &[usize],
        rng: &mut Rng,
    ) -> Option<BestSplit> {
        if idx.len() < ctx.params.min_samples_split {
            return None;
        }
        let feats = feature_subset(ctx.x.cols, ctx.params, rng);
        // Incremental SSE via prefix sums of y and y^2 over the sorted order.
        let y = ctx.y;
        find_best_split(ctx.x, idx, &feats, ctx.params.min_samples_leaf, |sorted, pos| {
            variance_reduction(y, sorted, pos)
        })
    }

    fn build_depth_first(
        &mut self,
        ctx: &RegBuildCtx,
        idx: Vec<usize>,
        depth: usize,
        rng: &mut Rng,
    ) -> usize {
        let stop = ctx
            .params
            .max_depth
            .map_or(false, |d| depth >= d);
        let split = if stop { None } else { self.split_of(ctx, &idx, rng) };
        match split {
            None => self.make_leaf(ctx, idx),
            Some(s) => {
                let (li, ri): (Vec<usize>, Vec<usize>) = idx
                    .iter()
                    .partition(|&&i| ctx.x[(i, s.feature)] <= s.threshold);
                let me = self.nodes.len();
                self.nodes.push(Node::Split {
                    feature: s.feature,
                    threshold: s.threshold,
                    left: 0,
                    right: 0,
                });
                let l = self.build_depth_first(ctx, li, depth + 1, rng);
                let r = self.build_depth_first(ctx, ri, depth + 1, rng);
                if let Node::Split { left, right, .. } = &mut self.nodes[me] {
                    *left = l;
                    *right = r;
                }
                me
            }
        }
    }

    /// Best-first growth to an exact leaf budget: repeatedly split the
    /// frontier leaf with the largest impurity improvement (how scikit-learn
    /// implements `max_leaf_nodes`).
    fn build_best_first(
        &mut self,
        ctx: &RegBuildCtx,
        idx: Vec<usize>,
        max_leaves: usize,
        rng: &mut Rng,
    ) {
        // Frontier entries: (node id, members, candidate split).
        self.nodes.push(Node::Leaf { payload: usize::MAX });
        let mut frontier: Vec<(usize, Vec<usize>, Option<BestSplit>)> = Vec::new();
        let split = self.split_of(ctx, &idx, rng);
        frontier.push((0, idx, split));
        let mut leaves = 1usize;
        let mut depth_ok = true;
        while leaves < max_leaves && depth_ok {
            // Pick the best splittable frontier entry.
            let pick = frontier
                .iter()
                .enumerate()
                .filter(|(_, (_, _, s))| s.is_some())
                .max_by(|a, b| {
                    let sa = a.1 .2.as_ref().unwrap().score;
                    let sb = b.1 .2.as_ref().unwrap().score;
                    sa.partial_cmp(&sb).unwrap()
                })
                .map(|(i, _)| i);
            let Some(pi) = pick else {
                depth_ok = false;
                continue;
            };
            let (node, members, split) = frontier.swap_remove(pi);
            let s = split.unwrap();
            let (li, ri): (Vec<usize>, Vec<usize>) = members
                .iter()
                .partition(|&&i| ctx.x[(i, s.feature)] <= s.threshold);
            let lnode = self.nodes.len();
            self.nodes.push(Node::Leaf { payload: usize::MAX });
            let rnode = self.nodes.len();
            self.nodes.push(Node::Leaf { payload: usize::MAX });
            self.nodes[node] = Node::Split {
                feature: s.feature,
                threshold: s.threshold,
                left: lnode,
                right: rnode,
            };
            let lsplit = self.split_of(ctx, &li, rng);
            let rsplit = self.split_of(ctx, &ri, rng);
            frontier.push((lnode, li, lsplit));
            frontier.push((rnode, ri, rsplit));
            leaves += 1;
        }
        // Materialize remaining frontier nodes as leaves.
        for (node, members, _) in frontier {
            let t = ctx.y.cols;
            let mut mean = vec![0.0; t];
            for &i in &members {
                for (m, &v) in mean.iter_mut().zip(ctx.y.row(i)) {
                    *m += v;
                }
            }
            for m in &mut mean {
                *m /= members.len() as f64;
            }
            let payload = self.leaf_values.len();
            self.leaf_values.push(mean);
            self.leaf_members.push(members);
            self.nodes[node] = Node::Leaf { payload };
        }
    }

    /// Index of the leaf payload a feature row lands in.
    pub fn apply(&self, row: &[f64]) -> usize {
        let mut node = 0usize;
        loop {
            match &self.nodes[node] {
                Node::Leaf { payload } => return *payload,
                Node::Split { feature, threshold, left, right } => {
                    node = if row[*feature] <= *threshold { *left } else { *right };
                }
            }
        }
    }

    /// Mean target vector of the leaf `row` lands in.
    pub fn predict(&self, row: &[f64]) -> &[f64] {
        &self.leaf_values[self.apply(row)]
    }

    /// Number of leaves (= clusters when used as a clustering device).
    pub fn n_leaves(&self) -> usize {
        self.leaf_values.len()
    }

    /// Longest root-to-leaf path, in splits.
    pub fn depth(&self) -> usize {
        fn walk(nodes: &[Node], id: usize) -> usize {
            match &nodes[id] {
                Node::Leaf { .. } => 0,
                Node::Split { left, right, .. } => {
                    1 + walk(nodes, *left).max(walk(nodes, *right))
                }
            }
        }
        walk(&self.nodes, 0)
    }
}

/// Total SSE reduction of splitting the sorted index list at `pos`, summed
/// over all output dimensions.
fn variance_reduction(y: &Matrix, sorted: &[usize], pos: usize) -> f64 {
    let t = y.cols;
    let n = sorted.len() as f64;
    let nl = pos as f64;
    let nr = n - nl;
    let mut score = 0.0;
    for out in 0..t {
        let mut sum_l = 0.0;
        let mut sum_all = 0.0;
        for (i, &s) in sorted.iter().enumerate() {
            let v = y[(s, out)];
            sum_all += v;
            if i < pos {
                sum_l += v;
            }
        }
        let sum_r = sum_all - sum_l;
        // SSE reduction = combined mean-shift term (constant total SS).
        score += sum_l * sum_l / nl + sum_r * sum_r / nr - sum_all * sum_all / n;
    }
    score
}

// ---------------------------------------------------------------------------
// Classifier.
// ---------------------------------------------------------------------------

/// Gini-impurity CART classifier — the runtime kernel selector of §5.1
/// (decision trees A/B/C) and the base learner of the random forest.
#[derive(Clone, Debug)]
pub struct TreeClassifier {
    /// Flat node arena; index 0 is the root.
    pub nodes: Vec<Node>,
    /// Class-count histogram per leaf.
    pub leaf_counts: Vec<Vec<usize>>,
    /// Number of distinct class labels seen in training.
    pub n_classes: usize,
    /// Feature count the tree was fitted on.
    pub n_features: usize,
}

impl TreeClassifier {
    /// Fit on features `x` (n x d) and class labels `y` (one per row).
    pub fn fit(x: &Matrix, y: &[usize], params: &TreeParams) -> TreeClassifier {
        assert_eq!(x.rows, y.len());
        assert!(x.rows > 0, "empty training set");
        let n_classes = y.iter().max().copied().unwrap_or(0) + 1;
        let mut tree = TreeClassifier {
            nodes: Vec::new(),
            leaf_counts: Vec::new(),
            n_classes,
            n_features: x.cols,
        };
        let mut rng = Rng::new(params.seed);
        let all: Vec<usize> = (0..x.rows).collect();
        tree.build(x, y, params, all, 0, &mut rng);
        tree
    }

    fn build(
        &mut self,
        x: &Matrix,
        y: &[usize],
        params: &TreeParams,
        idx: Vec<usize>,
        depth: usize,
        rng: &mut Rng,
    ) -> usize {
        let pure = idx.windows(2).all(|w| y[w[0]] == y[w[1]]);
        let stop = pure
            || params.max_depth.map_or(false, |d| depth >= d)
            || idx.len() < params.min_samples_split;
        let mut split = if stop {
            None
        } else {
            let feats = feature_subset(x.cols, params, rng);
            let nc = self.n_classes;
            find_best_split(x, &idx, &feats, params.min_samples_leaf, |sorted, pos| {
                gini_improvement(y, sorted, pos, nc)
            })
        };
        // Greedy CART can see exactly-zero improvement on every single
        // threshold of an impure node (XOR patterns). Like scikit-learn we
        // still split on the best balanced threshold so deeper levels can
        // resolve the interaction.
        if split.is_none() && !stop {
            split = fallback_median_split(x, &idx, params.min_samples_leaf);
        }
        let split = split;
        match split {
            None => {
                let mut counts = vec![0usize; self.n_classes];
                for &i in &idx {
                    counts[y[i]] += 1;
                }
                let payload = self.leaf_counts.len();
                self.leaf_counts.push(counts);
                self.nodes.push(Node::Leaf { payload });
                self.nodes.len() - 1
            }
            Some(s) => {
                let (li, ri): (Vec<usize>, Vec<usize>) =
                    idx.iter().partition(|&&i| x[(i, s.feature)] <= s.threshold);
                let me = self.nodes.len();
                self.nodes.push(Node::Split {
                    feature: s.feature,
                    threshold: s.threshold,
                    left: 0,
                    right: 0,
                });
                let l = self.build(x, y, params, li, depth + 1, rng);
                let r = self.build(x, y, params, ri, depth + 1, rng);
                if let Node::Split { left, right, .. } = &mut self.nodes[me] {
                    *left = l;
                    *right = r;
                }
                me
            }
        }
    }

    /// Majority class of the leaf `row` lands in (last-max tie-break).
    pub fn predict(&self, row: &[f64]) -> usize {
        let counts = self.leaf(row);
        counts
            .iter()
            .enumerate()
            .max_by_key(|&(_, &c)| c)
            .map(|(i, _)| i)
            .unwrap()
    }

    /// Class-count histogram of the leaf `row` lands in.
    pub fn leaf(&self, row: &[f64]) -> &[usize] {
        let mut node = 0usize;
        loop {
            match &self.nodes[node] {
                Node::Leaf { payload } => return &self.leaf_counts[*payload],
                Node::Split { feature, threshold, left, right } => {
                    node = if row[*feature] <= *threshold { *left } else { *right };
                }
            }
        }
    }

    /// Longest root-to-leaf path, in splits.
    pub fn depth(&self) -> usize {
        fn walk(nodes: &[Node], id: usize) -> usize {
            match &nodes[id] {
                Node::Leaf { .. } => 0,
                Node::Split { left, right, .. } => 1 + walk(nodes, *left).max(walk(nodes, *right)),
            }
        }
        if self.nodes.is_empty() {
            0
        } else {
            walk(&self.nodes, 0)
        }
    }

    /// Number of leaves.
    pub fn n_leaves(&self) -> usize {
        self.leaf_counts.len()
    }
}

// ---------------------------------------------------------------------------
// Flattened (SoA) inference.
// ---------------------------------------------------------------------------

/// Leaf marker in the flattened `feat` arrays — the wire contract of
/// [`FlatTree::into_parts`], shared with `classify::codegen`'s
/// `CompiledTree` so the two flattenings can never drift apart.
pub const FLAT_LEAF: u32 = u32::MAX;

/// Flattened structure-of-arrays evaluator for a trained
/// [`TreeClassifier`]: node features, thresholds and child pairs live in
/// three parallel arrays, and descent picks the child by indexing with the
/// comparison result instead of branching on enum variants. The per-node
/// work is one bounds-checked load per array and one compare — the
/// branch-predictable walk the serving hot path (cache misses, retuner
/// candidate scoring) runs instead of matching on [`Node`].
///
/// Predictions are defined to be bit-identical to
/// [`TreeClassifier::predict`] (same splits, same `<=` orientation, same
/// last-max tie-break on leaf counts); `classify/codegen.rs` applies the
/// same layout to destandardized thresholds for the compiled selector.
#[derive(Clone, Debug)]
pub struct FlatTree {
    /// Split feature per node; `FLAT_LEAF` marks a leaf.
    feat: Vec<u32>,
    /// Split threshold per node (0.0 at leaves).
    thr: Vec<f64>,
    /// `[left, right]` child indices per node; at a leaf, `[class, class]`.
    kids: Vec<[u32; 2]>,
}

impl FlatTree {
    /// Flatten a trained classifier. Leaf payloads collapse to their
    /// majority class with the same last-max tie-break as
    /// [`TreeClassifier::predict`].
    pub fn from_classifier(tree: &TreeClassifier) -> FlatTree {
        let mut feat = Vec::with_capacity(tree.nodes.len());
        let mut thr = Vec::with_capacity(tree.nodes.len());
        let mut kids = Vec::with_capacity(tree.nodes.len());
        for node in &tree.nodes {
            match node {
                Node::Leaf { payload } => {
                    let cls = tree.leaf_counts[*payload]
                        .iter()
                        .enumerate()
                        .max_by_key(|&(_, &c)| c)
                        .map(|(i, _)| i)
                        .unwrap_or(0) as u32;
                    feat.push(FLAT_LEAF);
                    thr.push(0.0);
                    kids.push([cls, cls]);
                }
                Node::Split { feature, threshold, left, right } => {
                    feat.push(*feature as u32);
                    thr.push(*threshold);
                    kids.push([*left as u32, *right as u32]);
                }
            }
        }
        FlatTree { feat, thr, kids }
    }

    /// Predicted class for a (standardized) feature row; identical to
    /// [`TreeClassifier::predict`] on the classifier this was built from.
    #[inline]
    pub fn predict(&self, row: &[f64]) -> usize {
        let mut i = 0usize;
        loop {
            let f = self.feat[i];
            if f == FLAT_LEAF {
                return self.kids[i][0] as usize;
            }
            let right = (row[f as usize] > self.thr[i]) as usize;
            i = self.kids[i][right] as usize;
        }
    }

    /// Number of nodes (splits + leaves) in the flattened table.
    pub fn n_nodes(&self) -> usize {
        self.feat.len()
    }

    /// Decompose into the parallel arrays (feature, threshold, children);
    /// `u32::MAX` in the feature array marks a leaf whose children both
    /// hold the class. `classify::codegen` uses this to rebase thresholds
    /// into raw-feature space without re-implementing the flattening.
    pub fn into_parts(self) -> (Vec<u32>, Vec<f64>, Vec<[u32; 2]>) {
        (self.feat, self.thr, self.kids)
    }
}

/// Median split on the first feature with more than one distinct value,
/// honoring `min_leaf`; used when no threshold shows positive improvement.
fn fallback_median_split(x: &Matrix, idx: &[usize], min_leaf: usize) -> Option<BestSplit> {
    for f in 0..x.cols {
        let mut vals: Vec<f64> = idx.iter().map(|&i| x[(i, f)]).collect();
        vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = vals.len();
        if n < 2 * min_leaf.max(1) {
            return None;
        }
        // Walk outward from the median to find a position where the value
        // actually changes and both sides satisfy min_leaf.
        let lo_bound = min_leaf.max(1);
        let hi_bound = n - min_leaf.max(1);
        let mid = n / 2;
        for delta in 0..n {
            for pos in [mid.saturating_sub(delta), mid + delta] {
                if pos < lo_bound || pos > hi_bound || pos == 0 || pos >= n {
                    continue;
                }
                if vals[pos] > vals[pos - 1] {
                    return Some(BestSplit {
                        feature: f,
                        threshold: (vals[pos - 1] + vals[pos]) / 2.0,
                        score: 0.0,
                    });
                }
            }
        }
    }
    None
}

/// Gini impurity decrease (unnormalized, weighted by counts).
fn gini_improvement(y: &[usize], sorted: &[usize], pos: usize, n_classes: usize) -> f64 {
    let mut left = vec![0usize; n_classes];
    let mut all = vec![0usize; n_classes];
    for (i, &s) in sorted.iter().enumerate() {
        all[y[s]] += 1;
        if i < pos {
            left[y[s]] += 1;
        }
    }
    let gini = |counts: &[usize]| -> f64 {
        let n: usize = counts.iter().sum();
        if n == 0 {
            return 0.0;
        }
        let nf = n as f64;
        1.0 - counts.iter().map(|&c| (c as f64 / nf).powi(2)).sum::<f64>()
    };
    let n = sorted.len() as f64;
    let nl = pos as f64;
    let nr = n - nl;
    let right: Vec<usize> = all.iter().zip(&left).map(|(&a, &l)| a - l).collect();
    gini(&all) - (nl / n) * gini(&left) - (nr / n) * gini(&right)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn xor_data() -> (Matrix, Vec<usize>) {
        let mut rows = Vec::new();
        let mut y = Vec::new();
        for &(a, b) in &[(0.0, 0.0), (0.0, 1.0), (1.0, 0.0), (1.0, 1.0)] {
            for i in 0..10 {
                let jitter = i as f64 * 0.001;
                rows.push(vec![a + jitter, b - jitter]);
                y.push(((a as i32) ^ (b as i32)) as usize);
            }
        }
        (Matrix::from_rows(&rows), y)
    }

    #[test]
    fn classifier_fits_xor() {
        let (x, y) = xor_data();
        let tree = TreeClassifier::fit(&x, &y, &TreeParams::default());
        for i in 0..x.rows {
            assert_eq!(tree.predict(x.row(i)), y[i]);
        }
        assert!(tree.depth() >= 2); // XOR is not linearly separable
    }

    #[test]
    fn classifier_depth_limit_respected() {
        let (x, y) = xor_data();
        let params = TreeParams { max_depth: Some(1), ..Default::default() };
        let tree = TreeClassifier::fit(&x, &y, &params);
        assert!(tree.depth() <= 1);
    }

    #[test]
    fn classifier_min_leaf_respected() {
        let (x, y) = xor_data();
        let params = TreeParams { min_samples_leaf: 15, ..Default::default() };
        let tree = TreeClassifier::fit(&x, &y, &params);
        for counts in &tree.leaf_counts {
            assert!(counts.iter().sum::<usize>() >= 15);
        }
    }

    #[test]
    fn regressor_exact_on_step_function() {
        let x = Matrix::from_rows(&(0..20).map(|i| vec![i as f64]).collect::<Vec<_>>());
        let y = Matrix::from_rows(
            &(0..20)
                .map(|i| vec![if i < 10 { 1.0 } else { 5.0 }, if i < 10 { -1.0 } else { 2.0 }])
                .collect::<Vec<_>>(),
        );
        let tree = TreeRegressor::fit(&x, &y, &TreeParams::default());
        assert_eq!(tree.predict(&[3.0]), &[1.0, -1.0]);
        assert_eq!(tree.predict(&[15.0]), &[5.0, 2.0]);
    }

    #[test]
    fn regressor_prediction_is_leaf_mean() {
        let x = Matrix::from_rows(&(0..12).map(|i| vec![(i % 4) as f64]).collect::<Vec<_>>());
        let y = Matrix::from_rows(
            &(0..12).map(|i| vec![i as f64]).collect::<Vec<_>>(),
        );
        let params = TreeParams { max_depth: Some(2), ..Default::default() };
        let tree = TreeRegressor::fit(&x, &y, &params);
        for leaf in 0..tree.n_leaves() {
            let members = &tree.leaf_members[leaf];
            let mean: f64 =
                members.iter().map(|&i| y[(i, 0)]).sum::<f64>() / members.len() as f64;
            assert!((tree.leaf_values[leaf][0] - mean).abs() < 1e-12);
        }
    }

    #[test]
    fn regressor_max_leaves_exact() {
        let x = Matrix::from_rows(&(0..40).map(|i| vec![i as f64]).collect::<Vec<_>>());
        let y = Matrix::from_rows(&(0..40).map(|i| vec![(i * i) as f64]).collect::<Vec<_>>());
        for budget in [2usize, 4, 6, 9] {
            let params = TreeParams { max_leaves: Some(budget), ..Default::default() };
            let tree = TreeRegressor::fit(&x, &y, &params);
            assert_eq!(tree.n_leaves(), budget, "budget {budget}");
            // Leaves partition the training set.
            let total: usize = tree.leaf_members.iter().map(|m| m.len()).sum();
            assert_eq!(total, 40);
        }
    }

    #[test]
    fn regressor_leaf_budget_caps_at_distinct_values() {
        // Only 3 distinct x values -> at most 3 leaves even with budget 10.
        let x = Matrix::from_rows(&(0..30).map(|i| vec![(i % 3) as f64]).collect::<Vec<_>>());
        let y = Matrix::from_rows(&(0..30).map(|i| vec![(i % 3) as f64 * 7.0]).collect::<Vec<_>>());
        let params = TreeParams { max_leaves: Some(10), ..Default::default() };
        let tree = TreeRegressor::fit(&x, &y, &params);
        assert_eq!(tree.n_leaves(), 3);
    }

    #[test]
    fn deterministic_given_seed() {
        let (x, y) = xor_data();
        let params = TreeParams { max_features: Some(1), seed: 5, ..Default::default() };
        let a = TreeClassifier::fit(&x, &y, &params);
        let b = TreeClassifier::fit(&x, &y, &params);
        let preds_equal = (0..x.rows).all(|i| a.predict(x.row(i)) == b.predict(x.row(i)));
        assert!(preds_equal);
    }

    #[test]
    fn flat_tree_matches_reference_walk_on_xor() {
        let (x, y) = xor_data();
        let tree = TreeClassifier::fit(&x, &y, &TreeParams::default());
        let flat = FlatTree::from_classifier(&tree);
        assert_eq!(flat.n_nodes(), tree.nodes.len());
        for i in 0..x.rows {
            assert_eq!(flat.predict(x.row(i)), tree.predict(x.row(i)), "row {i}");
        }
        // Off-grid probes exercise both branch directions at every split.
        for probe in [[-0.5, -0.5], [0.5, 0.5], [1.5, -0.2], [0.2, 1.5]] {
            assert_eq!(flat.predict(&probe), tree.predict(&probe), "{probe:?}");
        }
    }

    #[test]
    fn flat_tree_single_leaf_tree() {
        // A pure training set yields a single-leaf tree; the flat walk
        // must terminate immediately with that class.
        let x = Matrix::from_rows(&[vec![0.0], vec![1.0], vec![2.0]]);
        let y = vec![4usize, 4, 4];
        let tree = TreeClassifier::fit(&x, &y, &TreeParams::default());
        let flat = FlatTree::from_classifier(&tree);
        assert_eq!(flat.n_nodes(), 1);
        assert_eq!(flat.predict(&[7.0]), 4);
    }

    #[test]
    fn multioutput_split_uses_all_outputs() {
        // Output 0 is constant; output 1 steps at x=10. The tree must still
        // find the step via output 1's variance.
        let x = Matrix::from_rows(&(0..20).map(|i| vec![i as f64]).collect::<Vec<_>>());
        let y = Matrix::from_rows(
            &(0..20)
                .map(|i| vec![1.0, if i < 10 { 0.0 } else { 9.0 }])
                .collect::<Vec<_>>(),
        );
        let params = TreeParams { max_leaves: Some(2), ..Default::default() };
        let tree = TreeRegressor::fit(&x, &y, &params);
        assert_eq!(tree.n_leaves(), 2);
        assert!((tree.predict(&[0.0])[1] - 0.0).abs() < 1e-12);
        assert!((tree.predict(&[19.0])[1] - 9.0).abs() < 1e-12);
    }
}
