//! Support vector machines via simplified SMO (paper §5.1: LinearSVM and
//! RadialSVM comparators), with one-vs-rest multiclass reduction.

use crate::linalg::{dot, sq_dist, Matrix};
use crate::util::Rng;

/// SVM kernel function.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Kernel {
    /// Plain dot product.
    Linear,
    /// RBF with bandwidth gamma.
    Rbf(f64),
}

impl Kernel {
    #[inline]
    fn eval(&self, a: &[f64], b: &[f64]) -> f64 {
        match self {
            Kernel::Linear => dot(a, b),
            Kernel::Rbf(gamma) => (-gamma * sq_dist(a, b)).exp(),
        }
    }
}

/// SVM hyperparameters (simplified-SMO training knobs).
#[derive(Clone, Debug)]
pub struct SvmParams {
    /// Kernel function.
    pub kernel: Kernel,
    /// Soft-margin penalty C.
    pub c: f64,
    /// KKT violation tolerance.
    pub tol: f64,
    /// Passes without an alpha change before SMO stops.
    pub max_passes: usize,
    /// Seed for SMO's random second-multiplier choice.
    pub seed: u64,
}

impl Default for SvmParams {
    fn default() -> Self {
        SvmParams { kernel: Kernel::Linear, c: 1.0, tol: 1e-3, max_passes: 8, seed: 0 }
    }
}

/// Binary SVM trained with simplified SMO (Platt / Stanford CS229 variant).
#[derive(Clone, Debug)]
struct BinarySvm {
    alphas: Vec<f64>,
    bias: f64,
    /// Support vectors (rows) and their +-1 labels; only alphas > 0 kept.
    support: Matrix,
    sv_labels: Vec<f64>,
    kernel: Kernel,
}

impl BinarySvm {
    /// `y` in {-1.0, +1.0}.
    fn fit(x: &Matrix, y: &[f64], params: &SvmParams) -> BinarySvm {
        let n = x.rows;
        let mut alphas = vec![0.0f64; n];
        let mut bias = 0.0f64;
        let mut rng = Rng::new(params.seed);

        // Precompute the kernel matrix (n is a few hundred at most here).
        let mut kmat = Matrix::zeros(n, n);
        for i in 0..n {
            for j in i..n {
                let v = params.kernel.eval(x.row(i), x.row(j));
                kmat[(i, j)] = v;
                kmat[(j, i)] = v;
            }
        }
        let f = |alphas: &[f64], bias: f64, kmat: &Matrix, i: usize| -> f64 {
            let mut s = bias;
            for j in 0..n {
                if alphas[j] != 0.0 {
                    s += alphas[j] * y[j] * kmat[(j, i)];
                }
            }
            s
        };

        let mut passes = 0usize;
        let mut iters = 0usize;
        while passes < params.max_passes && iters < 200 {
            iters += 1;
            let mut changed = 0usize;
            for i in 0..n {
                let ei = f(&alphas, bias, &kmat, i) - y[i];
                let violates = (y[i] * ei < -params.tol && alphas[i] < params.c)
                    || (y[i] * ei > params.tol && alphas[i] > 0.0);
                if !violates {
                    continue;
                }
                let mut j = rng.below(n - 1);
                if j >= i {
                    j += 1;
                }
                let ej = f(&alphas, bias, &kmat, j) - y[j];
                let (ai_old, aj_old) = (alphas[i], alphas[j]);
                let (lo, hi) = if y[i] != y[j] {
                    ((aj_old - ai_old).max(0.0), (params.c + aj_old - ai_old).min(params.c))
                } else {
                    ((ai_old + aj_old - params.c).max(0.0), (ai_old + aj_old).min(params.c))
                };
                if (hi - lo).abs() < 1e-12 {
                    continue;
                }
                let eta = 2.0 * kmat[(i, j)] - kmat[(i, i)] - kmat[(j, j)];
                if eta >= 0.0 {
                    continue;
                }
                let mut aj = aj_old - y[j] * (ei - ej) / eta;
                aj = aj.clamp(lo, hi);
                if (aj - aj_old).abs() < 1e-5 {
                    continue;
                }
                let ai = ai_old + y[i] * y[j] * (aj_old - aj);
                alphas[i] = ai;
                alphas[j] = aj;
                let b1 = bias - ei
                    - y[i] * (ai - ai_old) * kmat[(i, i)]
                    - y[j] * (aj - aj_old) * kmat[(i, j)];
                let b2 = bias - ej
                    - y[i] * (ai - ai_old) * kmat[(i, j)]
                    - y[j] * (aj - aj_old) * kmat[(j, j)];
                bias = if ai > 0.0 && ai < params.c {
                    b1
                } else if aj > 0.0 && aj < params.c {
                    b2
                } else {
                    (b1 + b2) / 2.0
                };
                changed += 1;
            }
            if changed == 0 {
                passes += 1;
            } else {
                passes = 0;
            }
        }

        // Compact to support vectors.
        let sv_idx: Vec<usize> = (0..n).filter(|&i| alphas[i] > 1e-9).collect();
        let support = if sv_idx.is_empty() {
            Matrix::zeros(0, x.cols)
        } else {
            Matrix::from_rows(&sv_idx.iter().map(|&i| x.row(i).to_vec()).collect::<Vec<_>>())
        };
        BinarySvm {
            alphas: sv_idx.iter().map(|&i| alphas[i]).collect(),
            bias,
            support,
            sv_labels: sv_idx.iter().map(|&i| y[i]).collect(),
            kernel: params.kernel,
        }
    }

    fn decision(&self, row: &[f64]) -> f64 {
        let mut s = self.bias;
        for i in 0..self.support.rows {
            s += self.alphas[i] * self.sv_labels[i] * self.kernel.eval(self.support.row(i), row);
        }
        s
    }
}

/// One-vs-rest multiclass SVM.
#[derive(Clone, Debug)]
pub struct Svm {
    machines: Vec<BinarySvm>,
    /// Number of distinct class labels seen in training.
    pub n_classes: usize,
}

impl Svm {
    /// Train one binary machine per class (one-vs-rest).
    pub fn fit(x: &Matrix, y: &[usize], params: &SvmParams) -> Svm {
        assert_eq!(x.rows, y.len());
        let n_classes = y.iter().max().copied().unwrap_or(0) + 1;
        let machines = (0..n_classes)
            .map(|cls| {
                let ypm: Vec<f64> =
                    y.iter().map(|&l| if l == cls { 1.0 } else { -1.0 }).collect();
                let mut p = params.clone();
                p.seed = params.seed.wrapping_add(cls as u64);
                BinarySvm::fit(x, &ypm, &p)
            })
            .collect();
        Svm { machines, n_classes }
    }

    /// Class whose machine reports the largest decision value.
    pub fn predict(&self, row: &[f64]) -> usize {
        let mut best = 0usize;
        let mut best_score = f64::NEG_INFINITY;
        for (cls, m) in self.machines.iter().enumerate() {
            let s = m.decision(row);
            if s > best_score {
                best_score = s;
                best = cls;
            }
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn blobs2(seed: u64, sep: f64) -> (Matrix, Vec<usize>) {
        let mut rng = Rng::new(seed);
        let mut rows = Vec::new();
        let mut y = Vec::new();
        for (cls, (cx, cy)) in [(0.0, 0.0), (sep, sep)].iter().enumerate() {
            for _ in 0..25 {
                rows.push(vec![cx + rng.normal() * 0.4, cy + rng.normal() * 0.4]);
                y.push(cls);
            }
        }
        (Matrix::from_rows(&rows), y)
    }

    fn accuracy(svm: &Svm, x: &Matrix, y: &[usize]) -> f64 {
        let hits = (0..x.rows).filter(|&i| svm.predict(x.row(i)) == y[i]).count();
        hits as f64 / x.rows as f64
    }

    #[test]
    fn linear_separable() {
        let (x, y) = blobs2(1, 4.0);
        let svm = Svm::fit(&x, &y, &SvmParams::default());
        assert!(accuracy(&svm, &x, &y) > 0.95);
    }

    #[test]
    fn rbf_on_ring_data() {
        // Class 0 inside radius 1, class 1 on a ring at radius 3: not
        // linearly separable, RBF must handle it.
        let mut rng = Rng::new(2);
        let mut rows = Vec::new();
        let mut y = Vec::new();
        for i in 0..60 {
            let theta = rng.uniform() * std::f64::consts::TAU;
            let (r, cls) = if i % 2 == 0 { (rng.uniform() * 0.8, 0) } else { (3.0 + rng.normal() * 0.1, 1) };
            rows.push(vec![r * theta.cos(), r * theta.sin()]);
            y.push(cls);
        }
        let x = Matrix::from_rows(&rows);
        let rbf = Svm::fit(
            &x,
            &y,
            &SvmParams { kernel: Kernel::Rbf(1.0), c: 10.0, ..Default::default() },
        );
        assert!(accuracy(&rbf, &x, &y) > 0.95);
        let lin = Svm::fit(&x, &y, &SvmParams::default());
        assert!(accuracy(&lin, &x, &y) < accuracy(&rbf, &x, &y));
    }

    #[test]
    fn three_class_ovr() {
        let mut rng = Rng::new(3);
        let mut rows = Vec::new();
        let mut y = Vec::new();
        for (cls, (cx, cy)) in [(0.0, 0.0), (6.0, 0.0), (0.0, 6.0)].iter().enumerate() {
            for _ in 0..20 {
                rows.push(vec![cx + rng.normal() * 0.3, cy + rng.normal() * 0.3]);
                y.push(cls);
            }
        }
        let x = Matrix::from_rows(&rows);
        let svm = Svm::fit(&x, &y, &SvmParams::default());
        assert!(accuracy(&svm, &x, &y) > 0.95);
        assert_eq!(svm.n_classes, 3);
    }

    #[test]
    fn deterministic() {
        let (x, y) = blobs2(4, 3.0);
        let a = Svm::fit(&x, &y, &SvmParams::default());
        let b = Svm::fit(&x, &y, &SvmParams::default());
        for i in 0..x.rows {
            assert_eq!(a.predict(x.row(i)), b.predict(x.row(i)));
        }
    }
}
