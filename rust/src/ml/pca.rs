//! Principal component analysis (paper §3.3, §4.1.2).
//!
//! Implemented via eigendecomposition of the covariance matrix, with the
//! Gram-matrix trick when there are fewer samples than features (the usual
//! case here: ~240 training sizes x 640 kernel dimensions).

use crate::linalg::{eigh, Matrix};

/// Fitted PCA basis.
#[derive(Clone, Debug)]
pub struct Pca {
    /// Per-feature mean of the training data.
    pub mean: Vec<f64>,
    /// Principal axes as rows: components.row(i) is the i-th axis (unit
    /// norm), sorted by descending explained variance.
    pub components: Matrix,
    /// Variance explained by each component.
    pub explained_variance: Vec<f64>,
    /// `explained_variance` normalized to fractions of the total variance.
    pub explained_variance_ratio: Vec<f64>,
}

impl Pca {
    /// Fit up to `n_components` principal axes on `x` (rows = samples).
    pub fn fit(x: &Matrix, n_components: usize) -> Pca {
        let n = x.rows;
        let d = x.cols;
        let k_max = n_components.min(d).min(n.saturating_sub(1).max(1));

        let mean = x.col_means();
        let mut xc = x.clone();
        xc.center_rows(&mean);

        // Total variance (for ratios) straight from the centered data.
        let denom = (n.max(2) - 1) as f64;
        let total_var: f64 = xc.data.iter().map(|v| v * v).sum::<f64>() / denom;

        let (mut values, mut axes): (Vec<f64>, Vec<Vec<f64>>) = if n < d {
            // Gram trick: eigvecs u of (Xc Xc^T)/(n-1) give axes Xc^T u / norm.
            let mut gram = xc.matmul(&xc.transpose());
            for v in &mut gram.data {
                *v /= denom;
            }
            let e = eigh(&gram);
            let mut values = Vec::new();
            let mut axes = Vec::new();
            let xt = xc.transpose();
            for i in 0..k_max {
                let lam = e.values[i].max(0.0);
                let u = e.vectors.col(i);
                let mut axis = xt.matvec(&u);
                let norm = crate::linalg::norm2(&axis);
                if norm < 1e-12 || lam < 1e-15 {
                    continue;
                }
                for a in &mut axis {
                    *a /= norm;
                }
                values.push(lam);
                axes.push(axis);
            }
            (values, axes)
        } else {
            let e = eigh(&xc.covariance());
            let values: Vec<f64> = e.values[..k_max].iter().map(|&v| v.max(0.0)).collect();
            let axes: Vec<Vec<f64>> = (0..k_max).map(|i| e.vectors.col(i)).collect();
            (values, axes)
        };

        // Drop numerically-zero tail components.
        while let Some(&last) = values.last() {
            if last > 1e-12 * values[0].max(1e-300) {
                break;
            }
            values.pop();
            axes.pop();
        }
        if axes.is_empty() {
            values = vec![0.0];
            axes = vec![vec![0.0; d]];
        }

        let components = Matrix::from_rows(&axes);
        let ratio: Vec<f64> = if total_var > 0.0 {
            values.iter().map(|v| v / total_var).collect()
        } else {
            vec![0.0; values.len()]
        };
        Pca {
            mean,
            components,
            explained_variance: values,
            explained_variance_ratio: ratio,
        }
    }

    /// Number of principal axes actually kept.
    pub fn n_components(&self) -> usize {
        self.components.rows
    }

    /// Project rows of `x` onto the principal axes: (n x k) scores.
    pub fn transform(&self, x: &Matrix) -> Matrix {
        assert_eq!(x.cols, self.mean.len());
        let mut xc = x.clone();
        xc.center_rows(&self.mean);
        xc.matmul(&self.components.transpose())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    /// Anisotropic Gaussian blob: variance 9 along (1,1)/sqrt2, 1 across.
    fn blob(n: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        let mut rows = Vec::new();
        for _ in 0..n {
            let a = rng.normal() * 3.0;
            let b = rng.normal();
            let x = (a + b) / 2f64.sqrt();
            let y = (a - b) / 2f64.sqrt();
            rows.push(vec![x, y]);
        }
        Matrix::from_rows(&rows)
    }

    #[test]
    fn first_axis_is_dominant_direction() {
        let x = blob(500, 1);
        let pca = Pca::fit(&x, 2);
        let c0 = pca.components.row(0);
        // Axis ~ (1,1)/sqrt(2) up to sign.
        let ratio = c0[0] / c0[1];
        assert!((ratio - 1.0).abs() < 0.15, "axis ratio {ratio}");
        assert!(pca.explained_variance[0] > 5.0 * pca.explained_variance[1]);
    }

    #[test]
    fn ratios_sum_to_one_full_rank() {
        let x = blob(200, 2);
        let pca = Pca::fit(&x, 2);
        let total: f64 = pca.explained_variance_ratio.iter().sum();
        assert!((total - 1.0).abs() < 1e-8, "ratio total {total}");
    }

    #[test]
    fn ratios_descending_and_bounded() {
        let mut rng = Rng::new(5);
        let rows: Vec<Vec<f64>> = (0..30)
            .map(|_| (0..50).map(|_| rng.normal()).collect())
            .collect();
        let x = Matrix::from_rows(&rows);
        let pca = Pca::fit(&x, 15);
        for w in pca.explained_variance_ratio.windows(2) {
            assert!(w[0] >= w[1] - 1e-10);
        }
        for &r in &pca.explained_variance_ratio {
            assert!((0.0..=1.0 + 1e-9).contains(&r));
        }
    }

    #[test]
    fn gram_trick_matches_covariance_path() {
        // 10 samples x 4 features exercises covariance path; transpose the
        // sample count to exercise Gram; their explained variances agree on
        // a common dataset run through both (force via shapes).
        let mut rng = Rng::new(7);
        let rows: Vec<Vec<f64>> = (0..12)
            .map(|_| (0..5).map(|_| rng.normal()).collect())
            .collect();
        let x = Matrix::from_rows(&rows);
        let full = Pca::fit(&x, 5); // n > d: covariance path
        // Now embed the same data in 20 dims (pad zeros): n < d: Gram path.
        let rows_padded: Vec<Vec<f64>> = rows
            .iter()
            .map(|r| {
                let mut v = r.clone();
                v.resize(20, 0.0);
                v
            })
            .collect();
        let padded = Pca::fit(&Matrix::from_rows(&rows_padded), 5);
        for i in 0..4 {
            assert!(
                (full.explained_variance[i] - padded.explained_variance[i]).abs()
                    < 1e-8,
                "component {i}"
            );
        }
    }

    #[test]
    fn transform_decorrelates() {
        let x = blob(300, 9);
        let pca = Pca::fit(&x, 2);
        let scores = pca.transform(&x);
        let cov = scores.covariance();
        assert!(cov[(0, 1)].abs() < 0.05 * cov[(0, 0)], "off-diag {}", cov[(0, 1)]);
        // Score variance matches explained variance.
        assert!((cov[(0, 0)] - pca.explained_variance[0]).abs() < 1e-6);
    }

    #[test]
    fn components_unit_norm() {
        let x = blob(100, 11);
        let pca = Pca::fit(&x, 2);
        for i in 0..pca.n_components() {
            let n = crate::linalg::norm2(pca.components.row(i));
            assert!((n - 1.0).abs() < 1e-9);
        }
    }
}
