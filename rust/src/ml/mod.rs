//! Machine-learning substrate, written from scratch (the paper used
//! scikit-learn; nothing of the sort is vendored here, and the runtime must
//! stay Python-free anyway).
//!
//! Clustering (paper §4.1): [`kmeans`], [`pca`] (+k-means), [`spectral`],
//! [`hdbscan`], and decision-tree-as-clusterer via
//! [`decision_tree::TreeRegressor`] with a leaf budget.
//!
//! Classification (paper §5.1): [`decision_tree::TreeClassifier`],
//! [`knn`], [`svm`] (linear/RBF), [`random_forest`], [`mlp`].

pub mod decision_tree;
pub mod hdbscan;
pub mod kmeans;
pub mod knn;
pub mod mlp;
pub mod pca;
pub mod random_forest;
pub mod spectral;
pub mod svm;

pub use decision_tree::{FlatTree, TreeClassifier, TreeParams, TreeRegressor};
pub use hdbscan::{hdbscan, Hdbscan, HdbscanParams};
pub use kmeans::{kmeans, KMeans, KMeansParams};
pub use knn::Knn;
pub use mlp::{Mlp, MlpParams};
pub use pca::Pca;
pub use random_forest::{ForestParams, RandomForest};
pub use spectral::{spectral, Spectral, SpectralParams};
pub use svm::{Kernel, Svm, SvmParams};
