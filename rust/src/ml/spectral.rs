//! Spectral clustering (paper §4.1.3): RBF similarity graph -> normalized
//! Laplacian -> smallest-eigenvector embedding -> k-means.

use crate::linalg::{eigen::smallest_eigvec_embedding, sq_dist, Matrix};
use crate::ml::kmeans::{kmeans, KMeansParams};

/// Spectral-clustering hyperparameters.
#[derive(Clone, Debug)]
pub struct SpectralParams {
    /// Number of clusters (and embedding dimensions).
    pub k: usize,
    /// RBF width; if `None`, uses the median heuristic (1 / median sq-dist).
    pub gamma: Option<f64>,
    /// Seed for the k-means stage on the embedding.
    pub seed: u64,
}

impl SpectralParams {
    /// Defaults for `k` clusters: self-tuned gamma, seed 0.
    pub fn new(k: usize) -> Self {
        SpectralParams { k, gamma: None, seed: 0 }
    }

    /// Builder-style seed override.
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }
}

/// Spectral-clustering fit result.
#[derive(Clone, Debug)]
pub struct Spectral {
    /// Cluster assignment per input row.
    pub labels: Vec<usize>,
    /// The spectral embedding rows that were clustered (n x k).
    pub embedding: Matrix,
    /// The explicit RBF gamma, or 0.0 when self-tuning local scaling is used.
    pub gamma: f64,
}

/// Per-point local scale: distance to the 7th nearest neighbor
/// (Zelnik-Manor & Perona self-tuning spectral clustering).
fn local_scales(x: &Matrix) -> Vec<f64> {
    let n = x.rows;
    let k = 7usize.min(n.saturating_sub(1)).max(1);
    (0..n)
        .map(|i| {
            let mut d: Vec<f64> = (0..n)
                .filter(|&j| j != i)
                .map(|j| sq_dist(x.row(i), x.row(j)).sqrt())
                .collect();
            if d.is_empty() {
                return 1.0;
            }
            d.sort_by(|a, b| a.partial_cmp(b).unwrap());
            d[k - 1].max(1e-12)
        })
        .collect()
}

/// Cluster rows of `x` into `params.k` groups.
pub fn spectral(x: &Matrix, params: &SpectralParams) -> Spectral {
    let n = x.rows;
    assert!(n >= params.k, "spectral: k={} > n={}", params.k, n);

    // Affinity W (zero diagonal) and degree D. With an explicit gamma the
    // classic RBF kernel is used; otherwise self-tuning local scaling:
    // A_ij = exp(-d_ij^2 / (sigma_i * sigma_j)).
    let scales = if params.gamma.is_none() { local_scales(x) } else { vec![] };
    let gamma = params.gamma.unwrap_or(0.0);
    let mut w = Matrix::zeros(n, n);
    for i in 0..n {
        for j in (i + 1)..n {
            let d2 = sq_dist(x.row(i), x.row(j));
            let a = if params.gamma.is_some() {
                (-gamma * d2).exp()
            } else {
                (-d2 / (scales[i] * scales[j])).exp()
            };
            w[(i, j)] = a;
            w[(j, i)] = a;
        }
    }
    let degrees: Vec<f64> = (0..n).map(|i| w.row(i).iter().sum::<f64>()).collect();

    // Normalized Laplacian: L = I - D^-1/2 W D^-1/2.
    let mut lap = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..n {
            let norm = (degrees[i] * degrees[j]).sqrt();
            let wij = if norm > 1e-300 { w[(i, j)] / norm } else { 0.0 };
            lap[(i, j)] = if i == j { 1.0 - wij } else { -wij };
        }
    }

    // Embed on the k smallest eigenvectors, row-normalize, k-means.
    let mut emb = smallest_eigvec_embedding(&lap, params.k);
    for r in 0..n {
        let norm = crate::linalg::norm2(emb.row(r));
        if norm > 1e-300 {
            for v in emb.row_mut(r) {
                *v /= norm;
            }
        }
    }
    let km = kmeans(&emb, &KMeansParams::new(params.k).seed(params.seed));
    Spectral { labels: km.labels, embedding: emb, gamma }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    /// Two concentric rings: k-means fails on these in raw coordinates,
    /// spectral must separate them.
    fn rings(per: usize, seed: u64) -> (Matrix, Vec<usize>) {
        let mut rng = Rng::new(seed);
        let mut rows = Vec::new();
        let mut truth = Vec::new();
        for (i, radius) in [1.0f64, 5.0].iter().enumerate() {
            for _ in 0..per {
                let theta = rng.uniform() * std::f64::consts::TAU;
                let r = radius + rng.normal() * 0.05;
                rows.push(vec![r * theta.cos(), r * theta.sin()]);
                truth.push(i);
            }
        }
        (Matrix::from_rows(&rows), truth)
    }

    fn purity(labels: &[usize], truth: &[usize], k: usize) -> f64 {
        let mut correct = 0usize;
        for c in 0..k {
            let members: Vec<usize> =
                (0..labels.len()).filter(|&i| labels[i] == c).collect();
            if members.is_empty() {
                continue;
            }
            let mut counts = std::collections::HashMap::new();
            for &m in &members {
                *counts.entry(truth[m]).or_insert(0usize) += 1;
            }
            correct += counts.values().max().copied().unwrap_or(0);
        }
        correct as f64 / labels.len() as f64
    }

    #[test]
    fn separates_rings() {
        let (x, truth) = rings(60, 1);
        let fit = spectral(&x, &SpectralParams::new(2).seed(2));
        let p = purity(&fit.labels, &truth, 2);
        assert!(p > 0.95, "ring purity {p}");
    }

    #[test]
    fn separates_blobs() {
        let mut rng = Rng::new(3);
        let mut rows = Vec::new();
        let mut truth = Vec::new();
        for (i, (cx, cy)) in [(0.0, 0.0), (8.0, 8.0)].iter().enumerate() {
            for _ in 0..40 {
                rows.push(vec![cx + rng.normal() * 0.3, cy + rng.normal() * 0.3]);
                truth.push(i);
            }
        }
        let x = Matrix::from_rows(&rows);
        let fit = spectral(&x, &SpectralParams::new(2).seed(4));
        assert!(purity(&fit.labels, &truth, 2) > 0.98);
    }

    #[test]
    fn label_range_and_count() {
        let (x, _) = rings(25, 5);
        let fit = spectral(&x, &SpectralParams::new(2).seed(6));
        assert_eq!(fit.labels.len(), x.rows);
        assert!(fit.labels.iter().all(|&l| l < 2));
        assert_eq!(fit.gamma, 0.0); // self-tuning mode: no single gamma
    }

    #[test]
    fn explicit_gamma_respected() {
        let (x, _) = rings(20, 7);
        let fit = spectral(
            &x,
            &SpectralParams { k: 2, gamma: Some(0.5), seed: 8 },
        );
        assert_eq!(fit.gamma, 0.5);
    }
}
