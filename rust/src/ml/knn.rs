//! k-nearest-neighbor classification (paper §5.1: 1/3/7-NN comparators).

use crate::linalg::{sq_dist, Matrix};

/// k-NN classifier: memorizes the training set, votes at query time.
#[derive(Clone, Debug)]
pub struct Knn {
    x: Matrix,
    y: Vec<usize>,
    /// Neighbors consulted per query.
    pub k: usize,
    /// Number of distinct class labels seen in training.
    pub n_classes: usize,
}

impl Knn {
    /// Store the training set; `k` must be in `1..=x.rows`.
    pub fn fit(x: &Matrix, y: &[usize], k: usize) -> Knn {
        assert_eq!(x.rows, y.len());
        assert!(k >= 1 && k <= x.rows, "k={} for {} samples", k, x.rows);
        let n_classes = y.iter().max().copied().unwrap_or(0) + 1;
        Knn { x: x.clone(), y: y.to_vec(), k, n_classes }
    }

    /// Majority vote among the k nearest training points; ties break toward
    /// the class with the nearer aggregate (then the smaller label).
    pub fn predict(&self, row: &[f64]) -> usize {
        let mut dists: Vec<(f64, usize)> = (0..self.x.rows)
            .map(|i| (sq_dist(self.x.row(i), row), self.y[i]))
            .collect();
        dists.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let mut votes = vec![0usize; self.n_classes];
        let mut nearest_rank = vec![usize::MAX; self.n_classes];
        for (rank, &(_, cls)) in dists[..self.k].iter().enumerate() {
            votes[cls] += 1;
            nearest_rank[cls] = nearest_rank[cls].min(rank);
        }
        (0..self.n_classes)
            .max_by(|&a, &b| {
                votes[a]
                    .cmp(&votes[b])
                    .then(nearest_rank[b].cmp(&nearest_rank[a]))
            })
            .unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn data(seed: u64) -> (Matrix, Vec<usize>) {
        let mut rng = Rng::new(seed);
        let mut rows = Vec::new();
        let mut y = Vec::new();
        for (cls, (cx, cy)) in [(0.0, 0.0), (5.0, 5.0)].iter().enumerate() {
            for _ in 0..30 {
                rows.push(vec![cx + rng.normal() * 0.5, cy + rng.normal() * 0.5]);
                y.push(cls);
            }
        }
        (Matrix::from_rows(&rows), y)
    }

    #[test]
    fn one_nn_memorizes_training_set() {
        let (x, y) = data(1);
        let knn = Knn::fit(&x, &y, 1);
        for i in 0..x.rows {
            assert_eq!(knn.predict(x.row(i)), y[i]);
        }
    }

    #[test]
    fn k3_and_k7_classify_blobs() {
        let (x, y) = data(2);
        for k in [3, 7] {
            let knn = Knn::fit(&x, &y, k);
            assert_eq!(knn.predict(&[0.2, -0.1]), 0, "k={k}");
            assert_eq!(knn.predict(&[5.3, 4.8]), 1, "k={k}");
        }
    }

    #[test]
    fn tie_breaks_toward_nearer_class() {
        // 2-NN with one neighbor from each class: the nearer one wins.
        let x = Matrix::from_rows(&[vec![0.0], vec![1.0]]);
        let y = vec![0usize, 1usize];
        let knn = Knn::fit(&x, &y, 2);
        assert_eq!(knn.predict(&[0.2]), 0);
        assert_eq!(knn.predict(&[0.8]), 1);
    }

    #[test]
    #[should_panic]
    fn k_zero_rejected() {
        let x = Matrix::from_rows(&[vec![0.0]]);
        Knn::fit(&x, &[0], 0);
    }
}
