//! Bench: coordinator serving throughput and latency under different
//! batching configurations and selector policies.
//!
//! Runs on the SimBackend (synthetic manifest fallback) so it needs no
//! artifacts and no native XLA; pass `--features pjrt` plus real artifacts
//! to exercise the native path via `benches/runtime_exec.rs` instead.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use kernelsel::coordinator::{BatcherConfig, Coordinator, PoolConfig, SelectorPolicy};
use kernelsel::dataset::{config_by_name, GemmShape};
use kernelsel::runtime::Manifest;
use kernelsel::util::fill_buffer;

const CLIENTS: usize = 4;
const REQUESTS_PER_CLIENT: usize = 16;

fn run_once(policy: SelectorPolicy, cfg: BatcherConfig, label: &str) {
    let dir = PathBuf::from("artifacts");
    let coord = Arc::new(
        Coordinator::start_pool(
            dir,
            policy,
            PoolConfig { batcher: cfg, ..PoolConfig::default() },
        )
        .expect("start"),
    );
    let shapes = [
        GemmShape::new(128, 128, 128, 1),
        GemmShape::new(1024, 27, 64, 1),
        GemmShape::new(64, 2304, 128, 1),
    ];
    // Warm the executable cache.
    for s in shapes {
        let lhs = fill_buffer(1, s.batch * s.m * s.k);
        let rhs = fill_buffer(2, s.batch * s.k * s.n);
        let _ = coord.call(s, lhs, rhs);
    }

    let t0 = Instant::now();
    let mut joins = Vec::new();
    for c in 0..CLIENTS {
        let coord = coord.clone();
        joins.push(std::thread::spawn(move || {
            let mut lat = Vec::new();
            for i in 0..REQUESTS_PER_CLIENT {
                let s = shapes[(c + i) % shapes.len()];
                let lhs = fill_buffer((c * 37 + i) as u32, s.batch * s.m * s.k);
                let rhs = fill_buffer((c * 37 + i + 11) as u32, s.batch * s.k * s.n);
                let resp = coord.call(s, lhs, rhs).expect("call");
                assert!(resp.result.is_ok());
                lat.push(resp.latency.as_secs_f64());
            }
            lat
        }));
    }
    let mut latencies = Vec::new();
    for j in joins {
        latencies.extend(j.join().unwrap());
    }
    let wall = t0.elapsed().as_secs_f64();
    let total = CLIENTS * REQUESTS_PER_CLIENT;
    let metrics = Arc::try_unwrap(coord).ok().expect("sole owner").stop();
    let stats = kernelsel::util::Stats::from_secs(&latencies);
    println!(
        "{label:<34} {:>8.1} req/s  p50 {:>7.2} ms  p95 {:>7.2} ms  mean_batch {:.2}",
        total as f64 / wall,
        stats.p50 * 1e3,
        stats.p95 * 1e3,
        metrics.mean_batch_size()
    );
}

fn main() {
    let manifest = Manifest::load_or_synthetic(&PathBuf::from("artifacts"));
    let single = config_by_name(&manifest.single_best).unwrap().index();

    println!("== coordinator throughput ({CLIENTS} clients x {REQUESTS_PER_CLIENT} reqs) ==");
    for (label, max_batch, wait_us) in [
        ("no batching (max_batch=1)", 1usize, 0u64),
        ("batch<=8, wait 200us", 8, 200),
        ("batch<=16, wait 2ms", 16, 2000),
        ("batch<=32, wait 5ms", 32, 5000),
    ] {
        run_once(
            SelectorPolicy::Xla,
            BatcherConfig {
                max_batch,
                max_wait: Duration::from_micros(wait_us),
            },
            &format!("xla | {label}"),
        );
    }
    run_once(
        SelectorPolicy::Single(single),
        BatcherConfig::default(),
        "single-config | default batching",
    );
}
