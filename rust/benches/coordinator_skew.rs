//! Bench: scheduling under shape skew — the load-aware router + work
//! stealing pool vs the pure shape-affinity pool (PR-1 behavior: hash
//! routing, no spills, no steals), swept over shard counts on a uniform
//! and a 90/10-skewed shape mix — plus an **overload** scenario comparing
//! admission policies when offered load exceeds capacity by >= 3x.
//!
//! Each cell submits the whole workload asynchronously (open backlog, the
//! worst case for a pinned hot shape), then drains every response:
//! throughput is requests / makespan, latency percentiles come from the
//! per-request end-to-end latencies.
//!
//! The overload cells submit an instantaneous hot-shape burst many times
//! the pool's service capacity and report **goodput**: responses that
//! completed within an SLO (a fixed multiple of the measured warm
//! single-request service time) per second of makespan. `Unbounded`
//! serves everything but lets the queue grow without bound, so almost
//! nothing meets the SLO (latency collapse); `BoundedQueue` and
//! `DeadlineShed` refuse the infeasible tail up front, so what they admit
//! completes in bounded time and goodput stays at capacity.
//!
//!     cargo bench --bench coordinator_skew
//!     cargo bench --bench coordinator_skew -- --smoke \
//!         --json BENCH_pool.json --check-against ci/BENCH_pool.json
//!
//! `--smoke` shrinks the sweep for CI. `--json PATH` writes the
//! machine-readable `BENCH_pool.json` (schema in ARCHITECTURE.md).
//! `--check-against PATH` compares throughput per (mix, routing, shards,
//! admission) cell against a previously committed run and exits non-zero
//! on a >20% regression — the CI perf gate.

use std::path::PathBuf;
use std::time::Instant;

use kernelsel::coordinator::{
    AdmissionPolicy, Coordinator, PoolConfig, Routing, SelectorPolicy,
};
use kernelsel::dataset::GemmShape;
use kernelsel::util::json::{parse, Json};
use kernelsel::util::{fill_buffer, Stats};

/// Throughput may regress by at most this factor vs the committed baseline.
const REGRESSION_TOLERANCE: f64 = 0.80;

/// Overload SLO: a response is goodput if it completes within this many
/// multiples of the measured warm single-request service time.
const SLO_SERVICE_MULTIPLE: u32 = 16;

/// Enforced overload gate: each shedding policy's goodput must hold at
/// least this fraction of `Unbounded`'s (the strict verdict prints `>=`;
/// the exit-code gate leaves headroom for noisy shared runners — the
/// expected margin is several-x, so dipping under 80% means breakage).
const OVERLOAD_GATE_TOLERANCE: f64 = 0.80;

struct Cell {
    mix: &'static str,
    routing: &'static str,
    admission: &'static str,
    shards: usize,
    requests: usize,
    throughput_rps: f64,
    /// SLO-qualified successes per second of makespan. Equal to
    /// `throughput_rps` outside the overload scenario (no SLO applies).
    goodput_rps: f64,
    p50_ms: f64,
    /// p99 latency over *successful* responses (rejected/shed excluded).
    p99_ms: f64,
    spilled: usize,
    steals: usize,
    rejected: usize,
    shed: usize,
}

fn flag_value(args: &[String], name: &str) -> Option<String> {
    args.iter().position(|a| a == name).and_then(|i| args.get(i + 1)).cloned()
}

/// The request mix: `hot_share` of requests use the hot shape, the rest
/// cycle through the cold shapes. All shapes ship in both manifests.
fn workload(n: usize, hot_share: f64) -> Vec<GemmShape> {
    let hot = GemmShape::new(128, 128, 128, 1);
    let cold = [
        GemmShape::new(32, 32, 32, 1),
        GemmShape::new(64, 64, 64, 1),
        GemmShape::new(32, 32, 32, 4),
        GemmShape::new(64, 64, 64, 4),
    ];
    let period = 10usize;
    let hot_per_period = ((hot_share * period as f64).round() as usize).min(period);
    (0..n)
        .map(|i| {
            if i % period < hot_per_period {
                hot
            } else {
                cold[(i / period + i % period) % cold.len()]
            }
        })
        .collect()
}

/// Run one cell: async-submit the whole mix, drain everything, report.
fn run_cell(
    mix: &'static str,
    hot_share: f64,
    routing_name: &'static str,
    shards: usize,
    n: usize,
) -> Cell {
    let (routing, steal_min) = match routing_name {
        // PR-1 pure affinity: hash routing, stealing effectively disabled.
        "affinity" => (Routing::Affinity, usize::MAX),
        _ => (Routing::LoadAware, 2),
    };
    let coord = Coordinator::start_pool(
        PathBuf::from("artifacts"),
        SelectorPolicy::Xla,
        PoolConfig { shards, routing, steal_min, ..PoolConfig::default() },
    )
    .expect("start pool");

    let shapes = workload(n, hot_share);
    // Warm every executable cache so first-touch compiles stay out of the
    // measurement, then pre-generate inputs so the submit loop is tight.
    for s in [GemmShape::new(128, 128, 128, 1)]
        .iter()
        .chain(shapes.iter().take(40))
    {
        let lhs = fill_buffer(1, s.batch * s.m * s.k);
        let rhs = fill_buffer(2, s.batch * s.k * s.n);
        let _ = coord.call(*s, lhs, rhs);
    }
    let inputs: Vec<(GemmShape, Vec<f32>, Vec<f32>)> = shapes
        .iter()
        .enumerate()
        .map(|(i, s)| {
            (
                *s,
                fill_buffer(i as u32, s.batch * s.m * s.k),
                fill_buffer((i + 31) as u32, s.batch * s.k * s.n),
            )
        })
        .collect();

    let t0 = Instant::now();
    let rxs: Vec<_> = inputs
        .into_iter()
        .map(|(s, lhs, rhs)| coord.submit(s, lhs, rhs))
        .collect();
    let mut latencies = Vec::with_capacity(n);
    for rx in rxs {
        let resp = rx.recv().expect("response");
        assert!(resp.result.is_ok(), "{:?}", resp.result.err());
        latencies.push(resp.latency.as_secs_f64());
    }
    let wall = t0.elapsed().as_secs_f64();
    let report = coord.stop_detailed();
    let stats = Stats::from_secs(&latencies);
    Cell {
        mix,
        routing: routing_name,
        admission: "unbounded",
        shards,
        requests: n,
        throughput_rps: n as f64 / wall,
        goodput_rps: n as f64 / wall,
        p50_ms: stats.p50 * 1e3,
        p99_ms: stats.p99 * 1e3,
        spilled: report.total.spilled,
        steals: report.total.steals,
        rejected: 0,
        shed: 0,
    }
}

/// Run one overload cell: an instantaneous hot-shape burst of `n`
/// requests (offered at effectively infinite rate — far beyond 3x what
/// the shards can serve in any SLO window) under `policy`. The caller
/// measures `slo_secs` once and passes the same value to every policy,
/// so all cells in the scenario are judged against one SLO.
fn run_overload_cell(
    admission_name: &'static str,
    policy: AdmissionPolicy,
    shards: usize,
    n: usize,
    slo_secs: f64,
) -> Cell {
    let coord = Coordinator::start_pool(
        PathBuf::from("artifacts"),
        SelectorPolicy::Xla,
        PoolConfig { shards, admission: policy, ..PoolConfig::default() },
    )
    .expect("start pool");
    let hot = GemmShape::new(128, 128, 128, 1);
    // Warm the executable caches and the telemetry cost-hint cell.
    for i in 0..8u32 {
        let lhs = fill_buffer(i, 128 * 128);
        let rhs = fill_buffer(i + 3, 128 * 128);
        let _ = coord.call(hot, lhs, rhs);
    }
    let inputs: Vec<(Vec<f32>, Vec<f32>)> = (0..n)
        .map(|i| (fill_buffer(i as u32, 128 * 128), fill_buffer((i + 17) as u32, 128 * 128)))
        .collect();

    let t0 = Instant::now();
    let tickets: Vec<_> =
        inputs.into_iter().map(|(lhs, rhs)| coord.submit(hot, lhs, rhs)).collect();
    let mut ok_latencies = Vec::new();
    for ticket in tickets {
        if ticket.rejection().is_some() {
            continue; // counted exactly by the pool report below
        }
        let resp = ticket.wait();
        if resp.result.is_ok() {
            ok_latencies.push(resp.latency.as_secs_f64());
        }
        // Errors here are drain-time sheds (or real failures); both are
        // counted by their own exact pool counters, read from the report.
    }
    let wall = t0.elapsed().as_secs_f64();
    let report = coord.stop_detailed();
    let rejected = report.total.rejected;
    let shed = report.total.shed;
    let ok_in_slo = ok_latencies.iter().filter(|&&l| l <= slo_secs).count();
    let stats = if ok_latencies.is_empty() {
        None
    } else {
        Some(Stats::from_secs(&ok_latencies))
    };
    Cell {
        mix: "overload",
        routing: "load-aware",
        admission: admission_name,
        shards,
        requests: n,
        throughput_rps: ok_latencies.len() as f64 / wall,
        goodput_rps: ok_in_slo as f64 / wall,
        p50_ms: stats.as_ref().map_or(0.0, |s| s.p50 * 1e3),
        p99_ms: stats.as_ref().map_or(0.0, |s| s.p99 * 1e3),
        spilled: report.total.spilled,
        steals: report.total.steals,
        rejected,
        shed,
    }
}

/// Median warm single-request service time for the overload SLO: measured
/// on a fresh single-shard pool with sequential blocking calls, so queueing
/// never pollutes the estimate.
fn measure_service_secs() -> f64 {
    let coord = Coordinator::start_pool(
        PathBuf::from("artifacts"),
        SelectorPolicy::Xla,
        PoolConfig { shards: 1, ..PoolConfig::default() },
    )
    .expect("start pool");
    let hot = GemmShape::new(128, 128, 128, 1);
    let mut samples = Vec::new();
    for i in 0..11u32 {
        let lhs = fill_buffer(i, 128 * 128);
        let rhs = fill_buffer(i + 5, 128 * 128);
        let resp = coord.call(hot, lhs, rhs).expect("warm call");
        assert!(resp.result.is_ok());
        if i >= 3 {
            samples.push(resp.latency.as_secs_f64());
        }
    }
    coord.stop();
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    samples[samples.len() / 2]
}

fn cells_to_json(cells: &[Cell], mode: &str) -> Json {
    let entries: Vec<Json> = cells
        .iter()
        .map(|c| {
            Json::obj(vec![
                ("mix", Json::Str(c.mix.to_string())),
                ("routing", Json::Str(c.routing.to_string())),
                ("admission", Json::Str(c.admission.to_string())),
                ("shards", Json::Num(c.shards as f64)),
                ("requests", Json::Num(c.requests as f64)),
                ("throughput_rps", Json::Num(c.throughput_rps)),
                ("goodput_rps", Json::Num(c.goodput_rps)),
                ("p50_ms", Json::Num(c.p50_ms)),
                ("p99_ms", Json::Num(c.p99_ms)),
                ("spilled", Json::Num(c.spilled as f64)),
                ("steals", Json::Num(c.steals as f64)),
                ("rejected", Json::Num(c.rejected as f64)),
                ("shed", Json::Num(c.shed as f64)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("schema", Json::Str("kernelsel-bench-pool-v1".to_string())),
        ("mode", Json::Str(mode.to_string())),
        ("entries", Json::Arr(entries)),
    ])
}

/// Compare against a committed baseline; list every matching cell whose
/// throughput dropped below `REGRESSION_TOLERANCE x` baseline.
fn regressions(cells: &[Cell], baseline: &Json) -> Vec<String> {
    let mut out = Vec::new();
    let Some(entries) = baseline.get("entries").and_then(|e| e.as_arr()) else {
        out.push("baseline has no entries array".to_string());
        return out;
    };
    for b in entries {
        let (Some(mix), Some(routing), Some(shards), Some(rps)) = (
            b.get("mix").and_then(|v| v.as_str()),
            b.get("routing").and_then(|v| v.as_str()),
            b.get("shards").and_then(|v| v.as_usize()),
            b.get("throughput_rps").and_then(|v| v.as_f64()),
        ) else {
            continue;
        };
        if mix == "overload" {
            // Overload cells serve a deliberately tiny admitted subset —
            // their throughput is scheduler noise, not capacity — and the
            // bench already self-gates them on goodput vs Unbounded. Keep
            // them out of the 20% throughput gate even once a ratcheted
            // baseline carries them.
            continue;
        }
        // Pre-admission baselines carry no "admission" key: they describe
        // unbounded cells.
        let admission = b
            .get("admission")
            .and_then(|v| v.as_str())
            .unwrap_or("unbounded");
        let Some(cell) = cells.iter().find(|c| {
            c.mix == mix && c.routing == routing && c.shards == shards && c.admission == admission
        }) else {
            println!(
                "  (baseline {mix}/{routing}/{shards}/{admission} not in this sweep — skipped)"
            );
            continue;
        };
        let floor = rps * REGRESSION_TOLERANCE;
        if cell.throughput_rps < floor {
            out.push(format!(
                "{mix}/{routing}/{shards} shards: {:.1} req/s < {:.1} \
                 (baseline {:.1} x {:.0}% tolerance)",
                cell.throughput_rps,
                floor,
                rps,
                REGRESSION_TOLERANCE * 100.0
            ));
        }
    }
    out
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let json_path = flag_value(&args, "--json");
    let baseline_path = flag_value(&args, "--check-against");

    let (n, shard_counts): (usize, &[usize]) =
        if smoke { (200, &[1, 2, 4]) } else { (600, &[1, 2, 4, 8]) };
    let mode = if smoke { "smoke" } else { "full" };
    println!(
        "== coordinator_skew ({mode}): {n} reqs/cell, shards {shard_counts:?}, \
         sim backend ==\n"
    );

    let mut cells = Vec::new();
    for &(mix, hot_share) in &[("uniform", 0.0), ("skew90", 0.9)] {
        for &routing in &["affinity", "load-aware"] {
            for &shards in shard_counts {
                let cell = run_cell(mix, hot_share, routing, shards, n);
                println!(
                    "{:>8} {:>10} {} shard(s): {:>8.1} req/s  p50 {:>7.2} ms  \
                     p99 {:>7.2} ms  spilled {:>4}  steals {:>3}",
                    cell.mix,
                    cell.routing,
                    cell.shards,
                    cell.throughput_rps,
                    cell.p50_ms,
                    cell.p99_ms,
                    cell.spilled,
                    cell.steals,
                );
                cells.push(cell);
            }
        }
        println!();
    }

    // Overload scenario: an instantaneous hot-shape burst far beyond what
    // the shards can serve inside any SLO window (>= 3x capacity), judged
    // on goodput. Budgets are on the load-gauge scale (devsim-priced cost
    // hints): the hot 128^3 dispatch prices at ~44k gauge-ns plus 20k
    // queued overhead, so a 384k deadline admits a ~5-deep backlog.
    let service = measure_service_secs();
    let slo_secs = service * SLO_SERVICE_MULTIPLE as f64;
    let overload_shards = 2usize;
    let overload_n = if smoke { 160 } else { 320 };
    let overload_policies: [(&'static str, AdmissionPolicy); 3] = [
        ("unbounded", AdmissionPolicy::Unbounded),
        (
            "bounded-queue",
            AdmissionPolicy::BoundedQueue { max_inflight: 12, max_queue_ns: 50_000_000 },
        ),
        ("deadline-shed", AdmissionPolicy::DeadlineShed { deadline_ns: 384_000 }),
    ];
    println!(
        "overload: {overload_n}-request instantaneous burst, SLO {:.2} ms \
         ({SLO_SERVICE_MULTIPLE}x warm service {:.2} ms)",
        slo_secs * 1e3,
        service * 1e3
    );
    for (name, policy) in overload_policies {
        let cell = run_overload_cell(name, policy, overload_shards, overload_n, slo_secs);
        println!(
            "{:>8} {:>14} {} shard(s): goodput {:>7.1} req/s  served {:>7.1} req/s  \
             p50(ok) {:>7.2} ms  p99(ok) {:>7.2} ms  rejected {:>4}  shed {:>3}",
            cell.mix,
            cell.admission,
            cell.shards,
            cell.goodput_rps,
            cell.throughput_rps,
            cell.p50_ms,
            cell.p99_ms,
            cell.rejected,
            cell.shed,
        );
        cells.push(cell);
    }
    println!();

    // Acceptance verdict: at the widest sweep point, load-aware must beat
    // pure affinity on the skewed mix (throughput and p99) and must not
    // regress the uniform mix.
    let widest = *shard_counts.last().unwrap();
    let find = |mix: &str, routing: &str| {
        cells
            .iter()
            .find(|c| c.mix == mix && c.routing == routing && c.shards == widest)
            .unwrap()
    };
    let (sa, sl) = (find("skew90", "affinity"), find("skew90", "load-aware"));
    let (ua, ul) = (find("uniform", "affinity"), find("uniform", "load-aware"));
    println!(
        "skew90 @ {widest} shards: load-aware {:.2}x throughput, p99 {:.2} -> {:.2} ms  [{}]",
        sl.throughput_rps / sa.throughput_rps,
        sa.p99_ms,
        sl.p99_ms,
        if sl.throughput_rps > sa.throughput_rps && sl.p99_ms < sa.p99_ms {
            "OK"
        } else {
            "NOT BEATING AFFINITY"
        }
    );
    println!(
        "uniform @ {widest} shards: load-aware {:.2}x throughput  [{}]",
        ul.throughput_rps / ua.throughput_rps,
        if ul.throughput_rps >= 0.9 * ua.throughput_rps { "OK" } else { "REGRESSION" }
    );
    let over = |admission: &str| {
        cells
            .iter()
            .find(|c| c.mix == "overload" && c.admission == admission)
            .unwrap()
    };
    let (ou, ob, od) = (over("unbounded"), over("bounded-queue"), over("deadline-shed"));
    println!(
        "overload @ {overload_shards} shards: goodput unbounded {:.1} / bounded-queue {:.1} / \
         deadline-shed {:.1} req/s; p99(ok) {:.1} / {:.1} / {:.1} ms  [{}]",
        ou.goodput_rps,
        ob.goodput_rps,
        od.goodput_rps,
        ou.p99_ms,
        ob.p99_ms,
        od.p99_ms,
        if ob.goodput_rps >= ou.goodput_rps
            && od.goodput_rps >= ou.goodput_rps
            && ob.p99_ms <= slo_secs * 1e3
            && od.p99_ms <= slo_secs * 1e3
        {
            "OK"
        } else {
            "SHEDDING NOT BEATING COLLAPSE"
        }
    );
    // Enforced (with runner-noise headroom): unlike the skew verdict,
    // the overload cells have no committed baseline backstopping them in
    // --check-against, so the acceptance criterion gates here. A policy
    // that served nothing has p50/p99 encoded as 0.0 (no data) — that
    // must fail the gate, never satisfy the p99 check vacuously.
    let goodput_floor = OVERLOAD_GATE_TOLERANCE * ou.goodput_rps;
    let healthy = |c: &Cell| {
        c.throughput_rps > 0.0 // served at least one response at all
            && c.goodput_rps >= goodput_floor
            && c.p99_ms <= slo_secs * 1e3
    };
    let overload_gate_failed = !healthy(ob) || !healthy(od);

    if let Some(path) = json_path {
        let doc = cells_to_json(&cells, mode);
        std::fs::write(&path, doc.to_string() + "\n").expect("write BENCH_pool.json");
        println!("\nwrote {path}");
    }

    if let Some(path) = baseline_path {
        match std::fs::read_to_string(&path) {
            Ok(text) => {
                let baseline = parse(&text).expect("parse baseline BENCH_pool.json");
                let regs = regressions(&cells, &baseline);
                if regs.is_empty() {
                    println!(
                        "no throughput regression vs {path} ({:.0}% floor kept)",
                        REGRESSION_TOLERANCE * 100.0
                    );
                } else {
                    eprintln!("\nTHROUGHPUT REGRESSIONS vs {path}:");
                    for r in &regs {
                        eprintln!("  {r}");
                    }
                    std::process::exit(1);
                }
            }
            Err(e) => {
                // First run on a branch with no committed baseline yet: the
                // gate records instead of failing.
                println!("no baseline at {path} ({e}); skipping regression check");
            }
        }
    }

    if overload_gate_failed {
        eprintln!(
            "\nOVERLOAD GATE FAILED: each shedding policy must hold goodput >= {:.0}% of \
             Unbounded's with p99(ok) inside the SLO (see the overload verdict line above)",
            OVERLOAD_GATE_TOLERANCE * 100.0
        );
        std::process::exit(1);
    }
}
