//! Bench: scheduling under shape skew — the load-aware router + work
//! stealing pool vs the pure shape-affinity pool (PR-1 behavior: hash
//! routing, no spills, no steals), swept over shard counts on a uniform
//! and a 90/10-skewed shape mix — plus an **overload** scenario comparing
//! admission policies when offered load exceeds capacity by >= 3x.
//!
//! Each cell submits the whole workload asynchronously (open backlog, the
//! worst case for a pinned hot shape), then drains every response:
//! throughput is requests / makespan, latency percentiles come from the
//! per-request end-to-end latencies.
//!
//! The overload cells submit an instantaneous hot-shape burst many times
//! the pool's service capacity and report **goodput**: responses that
//! completed within an SLO (a fixed multiple of the measured warm
//! single-request service time) per second of makespan. `Unbounded`
//! serves everything but lets the queue grow without bound, so almost
//! nothing meets the SLO (latency collapse); `BoundedQueue` and
//! `DeadlineShed` refuse the infeasible tail up front, so what they admit
//! completes in bounded time and goodput stays at capacity.
//!
//! The **tenants** scenario is the adversarial-fairness gate for the
//! multi-tenant quota layer: three in-quota tenants send paced traffic
//! while a hostile tenant floods the same pool at ~10x its fair share.
//! With weighted-fair quotas on (`quota-fair`), every in-quota tenant
//! must keep p99 inside its SLO and hold >= 90% of the goodput it gets
//! running alone (`isolated`); the same run with quotas off
//! (`quota-off`) must demonstrably violate that — proving the quota
//! layer, not luck, is what isolates the tenants.
//!
//! The **explore** scenario is the acceptance gate for runtime
//! exploration: a pool whose shipped (bucket, config) matrix starts
//! >= 50% unmeasured arms seeded epsilon probing with a hard budget and
//! drives every cheap serving bucket sequentially. The exit code
//! enforces that >= 90% of the healthy shipped matrix is measured
//! within the probe budget AND that traced e2e p99 stays within 10% of
//! an identical no-explore control — probes redirect live requests onto
//! idle capacity, they never add load or displace in-SLO work. Explore
//! cells are self-gated and excluded from the throughput baseline gate.
//!
//! The **chaos** scenario is the robustness gate for fault injection,
//! variant quarantine and shard supervision: a seeded fault plan injects
//! transient errors + silent corruption against the deployed config for
//! the middle-sixth of a run (then a separate cell panics a worker), and
//! the exit code enforces that no corrupt result is ever delivered as
//! `Ok`, quarantine trips within a fixed window of onset, goodput
//! recovers to >= 80% of the fault-free run, and a worker panic costs at
//! most its in-flight batch. Chaos cells land under the optional `chaos`
//! key of BENCH_pool.json and never join the throughput baseline gate.
//!
//!     cargo bench --bench coordinator_skew
//!     cargo bench --bench coordinator_skew -- --smoke \
//!         --json BENCH_pool.json --check-against ci/BENCH_pool.json
//!
//! `--smoke` shrinks the sweep for CI. `--json PATH` writes the
//! machine-readable `BENCH_pool.json` (schema in ARCHITECTURE.md).
//! `--check-against PATH` compares throughput per (mix, routing, shards,
//! admission) cell against a previously committed run and exits non-zero
//! on a >20% regression — the CI perf gate. `--trace` runs the sweep
//! cells with the flight recorder on (ring sized to the cell) and prints
//! each cell's recorded/chain/dropped counts — a visibility aid, not a
//! gate (submit_hotpath --trace owns the overhead gate).

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use kernelsel::coordinator::{
    AdmissionPolicy, Coordinator, PoolConfig, Routing, SelectorPolicy, SloClass, SubmitError,
    TenantId, TenantSpec, TraceConfig,
};
use kernelsel::dataset::{config_by_name, GemmShape};
use kernelsel::engine::sim::host_gemm;
use kernelsel::engine::FaultPlan;
use kernelsel::runtime::Manifest;
use kernelsel::tuning::{ExploreConfig, ExploreStats};
use kernelsel::util::json::{parse, Json};
use kernelsel::util::{fill_buffer, Stats};

/// Throughput may regress by at most this factor vs the committed baseline.
const REGRESSION_TOLERANCE: f64 = 0.80;

/// Overload SLO: a response is goodput if it completes within this many
/// multiples of the measured warm single-request service time.
const SLO_SERVICE_MULTIPLE: u32 = 16;

/// Enforced overload gate: each shedding policy's goodput must hold at
/// least this fraction of `Unbounded`'s (the strict verdict prints `>=`;
/// the exit-code gate leaves headroom for noisy shared runners — the
/// expected margin is several-x, so dipping under 80% means breakage).
const OVERLOAD_GATE_TOLERANCE: f64 = 0.80;

struct Cell {
    mix: &'static str,
    routing: &'static str,
    admission: &'static str,
    shards: usize,
    requests: usize,
    throughput_rps: f64,
    /// SLO-qualified successes per second of makespan. Equal to
    /// `throughput_rps` outside the overload/tenants scenarios (no SLO
    /// applies).
    goodput_rps: f64,
    p50_ms: f64,
    /// p99 latency over *successful* responses (rejected/shed excluded).
    p99_ms: f64,
    spilled: usize,
    steals: usize,
    rejected: usize,
    shed: usize,
    /// Tenant name for the per-tenant cells of the adversarial scenario
    /// (`None` everywhere else; the JSON key is omitted when absent).
    tenant: Option<&'static str>,
}

fn flag_value(args: &[String], name: &str) -> Option<String> {
    args.iter().position(|a| a == name).and_then(|i| args.get(i + 1)).cloned()
}

/// The request mix: `hot_share` of requests use the hot shape, the rest
/// cycle through the cold shapes. All shapes ship in both manifests.
fn workload(n: usize, hot_share: f64) -> Vec<GemmShape> {
    let hot = GemmShape::new(128, 128, 128, 1);
    let cold = [
        GemmShape::new(32, 32, 32, 1),
        GemmShape::new(64, 64, 64, 1),
        GemmShape::new(32, 32, 32, 4),
        GemmShape::new(64, 64, 64, 4),
    ];
    let period = 10usize;
    let hot_per_period = ((hot_share * period as f64).round() as usize).min(period);
    (0..n)
        .map(|i| {
            if i % period < hot_per_period {
                hot
            } else {
                cold[(i / period + i % period) % cold.len()]
            }
        })
        .collect()
}

/// Run one cell: async-submit the whole mix, drain everything, report.
fn run_cell(
    mix: &'static str,
    hot_share: f64,
    routing_name: &'static str,
    shards: usize,
    n: usize,
    traced: bool,
) -> Cell {
    let (routing, steal_min) = match routing_name {
        // PR-1 pure affinity: hash routing, stealing effectively disabled.
        "affinity" => (Routing::Affinity, usize::MAX),
        _ => (Routing::LoadAware, 2),
    };
    let coord = Coordinator::start_pool(
        PathBuf::from("artifacts"),
        SelectorPolicy::Xla,
        PoolConfig {
            shards,
            routing,
            steal_min,
            // Ring sized to hold the whole cell (~4 chain events per
            // request plus batch/steal markers): the counts printed
            // below reflect the workload, not ring overflow.
            trace: traced
                .then_some(TraceConfig { capacity: (n * 6).next_power_of_two(), sample_every: 1 }),
            ..PoolConfig::default()
        },
    )
    .expect("start pool");

    let shapes = workload(n, hot_share);
    // Warm every executable cache so first-touch compiles stay out of the
    // measurement, then pre-generate inputs so the submit loop is tight.
    for s in [GemmShape::new(128, 128, 128, 1)]
        .iter()
        .chain(shapes.iter().take(40))
    {
        let lhs = fill_buffer(1, s.batch * s.m * s.k);
        let rhs = fill_buffer(2, s.batch * s.k * s.n);
        let _ = coord.call(*s, lhs, rhs);
    }
    let inputs: Vec<(GemmShape, Vec<f32>, Vec<f32>)> = shapes
        .iter()
        .enumerate()
        .map(|(i, s)| {
            (
                *s,
                fill_buffer(i as u32, s.batch * s.m * s.k),
                fill_buffer((i + 31) as u32, s.batch * s.k * s.n),
            )
        })
        .collect();

    let t0 = Instant::now();
    let rxs: Vec<_> = inputs
        .into_iter()
        .map(|(s, lhs, rhs)| coord.submit(s, lhs, rhs))
        .collect();
    let mut latencies = Vec::with_capacity(n);
    for rx in rxs {
        let resp = rx.recv().expect("response");
        assert!(resp.result.is_ok(), "{:?}", resp.result.err());
        latencies.push(resp.latency.as_secs_f64());
    }
    let wall = t0.elapsed().as_secs_f64();
    if let Some(rec) = coord.recorder() {
        println!(
            "{:>8} {:>10} {} shard(s): trace {} events, {} chains, {} dropped",
            mix,
            routing_name,
            shards,
            rec.recorded(),
            rec.chains(),
            rec.dropped(),
        );
    }
    let report = coord.stop_detailed();
    let stats = Stats::from_secs(&latencies);
    Cell {
        mix,
        routing: routing_name,
        admission: "unbounded",
        shards,
        requests: n,
        throughput_rps: n as f64 / wall,
        goodput_rps: n as f64 / wall,
        p50_ms: stats.p50 * 1e3,
        p99_ms: stats.p99 * 1e3,
        spilled: report.total.spilled,
        steals: report.total.steals,
        rejected: 0,
        shed: 0,
        tenant: None,
    }
}

/// Run one overload cell: an instantaneous hot-shape burst of `n`
/// requests (offered at effectively infinite rate — far beyond 3x what
/// the shards can serve in any SLO window) under `policy`. The caller
/// measures `slo_secs` once and passes the same value to every policy,
/// so all cells in the scenario are judged against one SLO.
fn run_overload_cell(
    admission_name: &'static str,
    policy: AdmissionPolicy,
    shards: usize,
    n: usize,
    slo_secs: f64,
) -> Cell {
    let coord = Coordinator::start_pool(
        PathBuf::from("artifacts"),
        SelectorPolicy::Xla,
        PoolConfig { shards, admission: policy, ..PoolConfig::default() },
    )
    .expect("start pool");
    let hot = GemmShape::new(128, 128, 128, 1);
    // Warm the executable caches and the telemetry cost-hint cell.
    for i in 0..8u32 {
        let lhs = fill_buffer(i, 128 * 128);
        let rhs = fill_buffer(i + 3, 128 * 128);
        let _ = coord.call(hot, lhs, rhs);
    }
    let inputs: Vec<(Vec<f32>, Vec<f32>)> = (0..n)
        .map(|i| (fill_buffer(i as u32, 128 * 128), fill_buffer((i + 17) as u32, 128 * 128)))
        .collect();

    let t0 = Instant::now();
    let tickets: Vec<_> =
        inputs.into_iter().map(|(lhs, rhs)| coord.submit(hot, lhs, rhs)).collect();
    let mut ok_latencies = Vec::new();
    for ticket in tickets {
        if ticket.rejection().is_some() {
            continue; // counted exactly by the pool report below
        }
        let resp = ticket.wait();
        if resp.result.is_ok() {
            ok_latencies.push(resp.latency.as_secs_f64());
        }
        // Errors here are drain-time sheds (or real failures); both are
        // counted by their own exact pool counters, read from the report.
    }
    let wall = t0.elapsed().as_secs_f64();
    let report = coord.stop_detailed();
    let rejected = report.total.rejected;
    let shed = report.total.shed;
    let ok_in_slo = ok_latencies.iter().filter(|&&l| l <= slo_secs).count();
    let stats = if ok_latencies.is_empty() {
        None
    } else {
        Some(Stats::from_secs(&ok_latencies))
    };
    Cell {
        mix: "overload",
        routing: "load-aware",
        admission: admission_name,
        shards,
        requests: n,
        throughput_rps: ok_latencies.len() as f64 / wall,
        goodput_rps: ok_in_slo as f64 / wall,
        p50_ms: stats.as_ref().map_or(0.0, |s| s.p50 * 1e3),
        p99_ms: stats.as_ref().map_or(0.0, |s| s.p99 * 1e3),
        spilled: report.total.spilled,
        steals: report.total.steals,
        rejected,
        shed,
        tenant: None,
    }
}

/// Median warm single-request service time for the overload SLO: measured
/// on a fresh single-shard pool with sequential blocking calls, so queueing
/// never pollutes the estimate.
fn measure_service_secs() -> f64 {
    let coord = Coordinator::start_pool(
        PathBuf::from("artifacts"),
        SelectorPolicy::Xla,
        PoolConfig { shards: 1, ..PoolConfig::default() },
    )
    .expect("start pool");
    let hot = GemmShape::new(128, 128, 128, 1);
    let mut samples = Vec::new();
    for i in 0..11u32 {
        let lhs = fill_buffer(i, 128 * 128);
        let rhs = fill_buffer(i + 5, 128 * 128);
        let resp = coord.call(hot, lhs, rhs).expect("warm call");
        assert!(resp.result.is_ok());
        if i >= 3 {
            samples.push(resp.latency.as_secs_f64());
        }
    }
    coord.stop();
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    samples[samples.len() / 2]
}

/// In-quota tenants of the adversarial scenario.
const IN_QUOTA_TENANTS: [&str; 3] = ["alpha", "beta", "gamma"];
/// The hostile tenant's id (in-quota tenants take 1..=3).
const HOSTILE_ID: u32 = 4;
/// Adversarial-scenario quota: with 4 equal-weight tenants each reserves
/// 3 admission-guaranteed slots (floor(12/4), remainder 0).
const TENANT_QUOTA_SLOTS: usize = 12;
/// In-quota goodput in the fair run must hold this fraction of the
/// tenant's isolated-run goodput.
const TENANT_ISOLATION_TOLERANCE: f64 = 0.90;

/// Pool for the adversarial scenario: 2 load-aware shards, the overload
/// bounded-queue policy, 3 in-quota tenants + 1 hostile tenant at equal
/// weight. `quota_slots = 0` turns the weighted-fair quota layer off
/// while keeping the lanes tracked — the "what PR 7 buys" control.
fn tenant_pool(quota_slots: usize, slo_secs: f64) -> Coordinator {
    let slo_wall = Duration::from_secs_f64(slo_secs);
    let mut tenants: Vec<TenantSpec> = IN_QUOTA_TENANTS
        .iter()
        .enumerate()
        .map(|(i, name)| {
            TenantSpec::new(TenantId(i as u32 + 1), *name, 1, SloClass::Standard)
                .with_slo_wall(slo_wall)
        })
        .collect();
    tenants.push(
        TenantSpec::new(TenantId(HOSTILE_ID), "hostile", 1, SloClass::Standard)
            .with_slo_wall(slo_wall),
    );
    Coordinator::start_pool(
        PathBuf::from("artifacts"),
        SelectorPolicy::Xla,
        PoolConfig {
            shards: 2,
            admission: AdmissionPolicy::BoundedQueue {
                max_inflight: 12,
                max_queue_ns: 50_000_000,
            },
            tenants,
            quota_slots,
            ..PoolConfig::default()
        },
    )
    .expect("start pool")
}

/// One paced in-quota client: `n` hot-shape requests at a fixed interval
/// (open loop — a late response never delays the next submit), drained
/// after the submit loop. Returns (ok latencies, rejected count).
fn paced_tenant_traffic(
    coord: &Coordinator,
    tenant: TenantId,
    n: usize,
    interval: Duration,
) -> (Vec<f64>, usize) {
    let hot = GemmShape::new(128, 128, 128, 1);
    let lhs = fill_buffer(tenant.0, 128 * 128);
    let rhs = fill_buffer(tenant.0 + 7, 128 * 128);
    let start = Instant::now();
    let mut tickets = Vec::with_capacity(n);
    for i in 0..n {
        let target = start + interval * i as u32;
        let now = Instant::now();
        if target > now {
            std::thread::sleep(target - now);
        }
        tickets.push(coord.submit_as(tenant, hot, lhs.clone(), rhs.clone()));
    }
    let mut latencies = Vec::with_capacity(n);
    let mut rejected = 0usize;
    for ticket in tickets {
        if ticket.rejection().is_some() {
            rejected += 1;
            continue;
        }
        let resp = ticket.wait();
        if resp.result.is_ok() {
            latencies.push(resp.latency.as_secs_f64());
        }
    }
    (latencies, rejected)
}

/// The hostile tenant: a closed-loop flood at concurrency 32 — far past
/// its fair share — that refills freed slots instantly until `stop` is
/// set. Rejections back off by the pool's own retry-after hint (capped at
/// 1 ms), so the flood is relentless without starving the shard threads
/// of CPU. Returns (admitted, rejected) counts.
fn hostile_flood(coord: &Coordinator, stop: &AtomicBool) -> (usize, usize) {
    let hot = GemmShape::new(128, 128, 128, 1);
    let lhs = fill_buffer(99, 128 * 128);
    let rhs = fill_buffer(101, 128 * 128);
    let mut inflight = std::collections::VecDeque::with_capacity(32);
    let mut admitted = 0usize;
    let mut rejected = 0usize;
    while !stop.load(Ordering::Acquire) {
        let ticket = coord.submit_as(TenantId(HOSTILE_ID), hot, lhs.clone(), rhs.clone());
        match ticket.rejection() {
            Some(SubmitError::Rejected { retry_after_hint, .. }) => {
                rejected += 1;
                let nap = retry_after_hint
                    .unwrap_or(Duration::from_micros(100))
                    .min(Duration::from_millis(1));
                std::thread::sleep(nap);
            }
            None => {
                admitted += 1;
                inflight.push_back(ticket);
                if inflight.len() >= 32 {
                    let _ = inflight.pop_front().expect("nonempty").wait();
                }
            }
        }
    }
    for ticket in inflight {
        let _ = ticket.wait();
    }
    (admitted, rejected)
}

/// Run the adversarial scenario on one pool configuration: 3 paced
/// in-quota tenants + the hostile flood, all concurrent. Returns one Cell
/// per in-quota tenant (hostile admit/reject totals go to stdout only —
/// its "goodput" is meaningless by construction).
fn run_adversarial(
    admission_name: &'static str,
    quota_slots: usize,
    n: usize,
    interval: Duration,
    slo_secs: f64,
) -> Vec<Cell> {
    let coord = Arc::new(tenant_pool(quota_slots, slo_secs));
    // Warm the executable caches and the drain-rate EWMA before anything
    // is measured or flooded.
    let hot = GemmShape::new(128, 128, 128, 1);
    for i in 0..8u32 {
        let _ = coord.call(hot, fill_buffer(i, 128 * 128), fill_buffer(i + 3, 128 * 128));
    }
    let stop = Arc::new(AtomicBool::new(false));
    let flood = {
        let coord = coord.clone();
        let stop = stop.clone();
        std::thread::spawn(move || hostile_flood(&coord, &stop))
    };
    let t0 = Instant::now();
    let mut clients = Vec::new();
    for i in 0..IN_QUOTA_TENANTS.len() {
        let coord = coord.clone();
        clients.push(std::thread::spawn(move || {
            paced_tenant_traffic(&coord, TenantId(i as u32 + 1), n, interval)
        }));
    }
    let outcomes: Vec<(Vec<f64>, usize)> =
        clients.into_iter().map(|j| j.join().expect("tenant client")).collect();
    let wall = t0.elapsed().as_secs_f64();
    stop.store(true, Ordering::Release);
    let (hostile_admitted, hostile_rejected) = flood.join().expect("hostile client");
    let report = Arc::try_unwrap(coord).ok().expect("sole owner").stop_detailed();
    println!(
        "{:>8} {:>14} hostile: admitted {hostile_admitted}, rejected {hostile_rejected}",
        "tenants", admission_name,
    );
    IN_QUOTA_TENANTS
        .iter()
        .copied()
        .zip(outcomes)
        .map(|(name, (latencies, rejected))| {
            let in_slo = latencies.iter().filter(|&&l| l <= slo_secs).count();
            let stats =
                if latencies.is_empty() { None } else { Some(Stats::from_secs(&latencies)) };
            Cell {
                mix: "tenants",
                routing: "load-aware",
                admission: admission_name,
                shards: 2,
                requests: n,
                throughput_rps: latencies.len() as f64 / wall,
                goodput_rps: in_slo as f64 / wall,
                p50_ms: stats.as_ref().map_or(0.0, |s| s.p50 * 1e3),
                p99_ms: stats.as_ref().map_or(0.0, |s| s.p99 * 1e3),
                spilled: report.total.spilled,
                steals: report.total.steals,
                rejected,
                shed: report.total.shed,
                tenant: Some(name),
            }
        })
        .collect()
}

/// Isolated baseline: one in-quota tenant alone on the quota-enabled
/// pool, same pacing — the goodput a tenant is entitled to expect.
fn run_isolated(n: usize, interval: Duration, slo_secs: f64) -> Cell {
    let coord = tenant_pool(TENANT_QUOTA_SLOTS, slo_secs);
    let hot = GemmShape::new(128, 128, 128, 1);
    for i in 0..8u32 {
        let _ = coord.call(hot, fill_buffer(i, 128 * 128), fill_buffer(i + 3, 128 * 128));
    }
    let t0 = Instant::now();
    let (latencies, rejected) = paced_tenant_traffic(&coord, TenantId(1), n, interval);
    let wall = t0.elapsed().as_secs_f64();
    let report = coord.stop_detailed();
    let in_slo = latencies.iter().filter(|&&l| l <= slo_secs).count();
    let stats = if latencies.is_empty() { None } else { Some(Stats::from_secs(&latencies)) };
    Cell {
        mix: "tenants",
        routing: "load-aware",
        admission: "isolated",
        shards: 2,
        requests: n,
        throughput_rps: latencies.len() as f64 / wall,
        goodput_rps: in_slo as f64 / wall,
        p50_ms: stats.as_ref().map_or(0.0, |s| s.p50 * 1e3),
        p99_ms: stats.as_ref().map_or(0.0, |s| s.p99 * 1e3),
        spilled: report.total.spilled,
        steals: report.total.steals,
        rejected,
        shed: report.total.shed,
        tenant: Some("alpha"),
    }
}

/// Explore: fraction of the healthy shipped (bucket, config) matrix that
/// must hold at least one measured sample by the end of the run.
const EXPLORE_COVERAGE_MIN: f64 = 0.90;
/// Explore: the exploring pool's traced e2e p99 may exceed the
/// no-explore control's by at most this factor.
const EXPLORE_P99_TOLERANCE: f64 = 1.10;
/// Explore: lifetime probe cap — coverage must be reached within it.
const EXPLORE_BUDGET: u64 = 200;
/// The three multi-hundred-MFLOP synthetic buckets, too slow for a tight
/// sequential host-GEMM loop. The explore scenario pre-seeds them as
/// already-measured history (a deployment with telemetry for its heavy
/// shapes but none for the rest of the matrix) and drives the other
/// eleven — which also sets up the scenario's precondition: >= 50% of
/// the shipped matrix starts unmeasured.
const EXPLORE_HEAVY: [(usize, usize, usize, usize); 3] =
    [(512, 784, 512, 1), (512, 784, 512, 16), (196, 4608, 512, 1)];

/// Run one explore-scenario cell: a 2-shard traced pool, the heavy
/// buckets pre-seeded as measured, then `n` sequential blocking calls
/// round-robining the cheap buckets. Sequential submission keeps every
/// shard near-idle at submit time, so the only thing separating the
/// explore cell from the control is the probe redirects themselves.
/// Returns the cell, the final `(measured, total)` coverage, and the
/// shutdown exploration counters.
fn run_explore_cell(
    admission_name: &'static str,
    explore: Option<ExploreConfig>,
    n: usize,
) -> (Cell, (usize, usize), ExploreStats) {
    let coord = Coordinator::start_pool(
        PathBuf::from("artifacts"),
        SelectorPolicy::Xla,
        PoolConfig {
            shards: 2,
            explore,
            trace: Some(TraceConfig {
                capacity: (n * 6).next_power_of_two(),
                sample_every: 1,
            }),
            ..PoolConfig::default()
        },
    )
    .expect("start pool");
    // Pre-seed the heavy buckets with 3 samples per deployed config (the
    // sink's pricing threshold), leaving the driven matrix unmeasured.
    let manifest = Manifest::synthetic();
    let deployed: Vec<usize> = manifest
        .deployed
        .iter()
        .map(|name| config_by_name(name).expect("deployed config").index())
        .collect();
    for &(m, k, nn, b) in &EXPLORE_HEAVY {
        let shape = GemmShape::new(m, k, nn, b);
        for &cfg in &deployed {
            for _ in 0..3 {
                coord.telemetry().record(shape, Some(cfg), shape.flops() / 4e10);
            }
        }
    }
    let driven: Vec<GemmShape> = manifest
        .matmul_shapes()
        .into_iter()
        .filter(|dims| !EXPLORE_HEAVY.contains(dims))
        .map(|(m, k, nn, b)| GemmShape::new(m, k, nn, b))
        .collect();
    let (m0, total0) = coord.explore_coverage(1);
    assert!(
        (total0 - m0) * 2 >= total0,
        "explore precondition: >= 50% of the shipped matrix must start unmeasured \
         ({m0}/{total0} already measured)"
    );
    // One warming pass keeps first-touch compiles out of the measured
    // loop (on the explore cell it also fires each bucket's first-sight).
    for s in &driven {
        let lhs = fill_buffer(1, s.batch * s.m * s.k);
        let rhs = fill_buffer(2, s.batch * s.k * s.n);
        let _ = coord.call(*s, lhs, rhs);
    }
    let t_run = Instant::now();
    let mut latencies = Vec::with_capacity(n);
    for i in 0..n {
        let s = driven[i % driven.len()];
        let lhs = fill_buffer(i as u32, s.batch * s.m * s.k);
        let rhs = fill_buffer((i + 13) as u32, s.batch * s.k * s.n);
        let resp = coord.call(s, lhs, rhs).expect("explore call");
        assert!(resp.result.is_ok(), "{:?}", resp.result.err());
        latencies.push(resp.latency.as_secs_f64());
    }
    let wall = t_run.elapsed().as_secs_f64();
    // The first-sight micro-benchmarks run off the hot path on the
    // seeder thread; poll until their telemetry lands (or 5 s).
    let deadline = Instant::now() + Duration::from_secs(5);
    let mut coverage = coord.explore_coverage(1);
    while explore.is_some()
        && (coverage.0 as f64) < EXPLORE_COVERAGE_MIN * coverage.1 as f64
        && Instant::now() < deadline
    {
        std::thread::sleep(Duration::from_millis(20));
        coverage = coord.explore_coverage(1);
    }
    let report = coord.stop_detailed();
    let lat = Stats::from_secs(&latencies);
    (
        Cell {
            mix: "explore",
            routing: "load-aware",
            admission: admission_name,
            shards: 2,
            requests: n,
            throughput_rps: n as f64 / wall,
            goodput_rps: n as f64 / wall,
            p50_ms: lat.p50 * 1e3,
            p99_ms: lat.p99 * 1e3,
            spilled: report.total.spilled,
            steals: report.total.steals,
            rejected: report.total.rejected,
            shed: report.total.shed,
            tenant: None,
        },
        coverage,
        report.explore,
    )
}

/// Chaos: quarantine must trip within this many requests of fault onset.
const CHAOS_TRIP_WINDOW: usize = 64;
/// Chaos: final-third goodput must hold this fraction of the fault-free
/// run's final-third goodput (faults stop at `n/3`, so by the last third
/// quarantine + restore must have recovered the pool).
const CHAOS_RECOVERY_TOLERANCE: f64 = 0.80;

/// One self-gating robustness cell: a seeded fault plan injected mid-run
/// against a live pool (schema: the `chaos` array of BENCH_pool.json —
/// see ARCHITECTURE.md §9; excluded from the throughput baseline gate).
struct ChaosCell {
    scenario: &'static str,
    requests: usize,
    ok: usize,
    failed: usize,
    /// `Ok` responses whose payload differed from the reference result —
    /// silent corruption delivered as success. Must be zero, always.
    corrupt_ok: usize,
    trips: usize,
    probes: usize,
    restores: usize,
    respawns: usize,
    /// Requests between fault onset and the first quarantine trip
    /// (`None` = never tripped, or not applicable to the scenario).
    trip_latency: Option<usize>,
    /// Final-third goodput vs the fault-free baseline's (1.0 = fully
    /// recovered; only the fault scenario measures it).
    recovery_ratio: f64,
}

/// First sample value of an exposition counter family (`0` when absent) —
/// how the chaos loop watches quarantine trips land mid-run.
fn prom_counter(text: &str, name: &str) -> usize {
    text.lines()
        .filter(|l| !l.starts_with('#'))
        .find(|l| l.split([' ', '{']).next() == Some(name))
        .and_then(|l| l.rsplit(' ').next())
        .and_then(|v| v.parse::<f64>().ok())
        .map_or(0, |v| v as usize)
}

/// Sequential hot-shape drive loop shared by the chaos cells and their
/// fault-free baseline: returns (ok, failed, corrupt_ok, first trip seen
/// at request index, final-third ok/sec). Every `Ok` payload is checked
/// bit-for-bit against the reference GEMM — a corrupted result delivered
/// as success is the one unacceptable outcome. After a "worker died"
/// failure the loop pauses briefly so the panicking worker's unwind
/// finishes before the next submit (which then triggers the respawn).
fn drive_chaos(coord: &Coordinator, n: usize) -> (usize, usize, usize, Option<usize>, f64) {
    let hot = GemmShape::new(128, 128, 128, 1);
    let (mut ok, mut failed, mut corrupt_ok) = (0usize, 0usize, 0usize);
    let mut first_trip = None;
    let mut final_third_t0 = Instant::now();
    let mut final_third_ok = 0usize;
    for i in 0..n {
        if i == 2 * n / 3 {
            final_third_t0 = Instant::now();
        }
        let lhs = fill_buffer(i as u32, 128 * 128);
        let rhs = fill_buffer((i + 17) as u32, 128 * 128);
        let resp = coord.call(hot, lhs.clone(), rhs.clone()).expect("chaos call");
        match resp.result {
            Ok(out) => {
                ok += 1;
                if i >= 2 * n / 3 {
                    final_third_ok += 1;
                }
                if out != host_gemm(&hot, &lhs, &rhs).expect("reference gemm") {
                    corrupt_ok += 1;
                }
            }
            Err(e) => {
                failed += 1;
                if e.contains("worker died") {
                    std::thread::sleep(Duration::from_millis(100));
                }
            }
        }
        if first_trip.is_none()
            && prom_counter(&coord.metrics_text(), "kernelsel_quarantine_trips_total") > 0
        {
            first_trip = Some(i);
        }
    }
    let final_rps = final_third_ok as f64 / final_third_t0.elapsed().as_secs_f64().max(1e-9);
    (ok, failed, corrupt_ok, first_trip, final_rps)
}

/// Pool for the chaos cells: one shard (execution index == request index,
/// so the seeded fault schedule is exact), the deployed single-best
/// selector (quarantine tracks per-config outcomes — the XLA fallback is
/// untracked by design), optionally wrapped by `plan`.
fn chaos_pool(plan: Option<FaultPlan>) -> Coordinator {
    let best = config_by_name(&Manifest::synthetic().single_best)
        .expect("synthetic best config")
        .index();
    Coordinator::start_pool(
        PathBuf::from("artifacts"),
        SelectorPolicy::Single(best),
        PoolConfig { shards: 1, fault: plan, ..PoolConfig::default() },
    )
    .expect("start pool")
}

/// Run the chaos scenario: a fault cell (transient + corruption burst
/// targeted at the deployed config, window `[n/6, n/3)`) judged against
/// a fault-free baseline, plus a worker-panic cell. Appends every gate
/// violation to `failures`.
fn run_chaos_cells(n: usize, failures: &mut Vec<String>) -> Vec<ChaosCell> {
    let best = config_by_name(&Manifest::synthetic().single_best)
        .expect("synthetic best config")
        .index();

    // Fault-free baseline: the goodput yardstick (and a standing check
    // that the reference comparison itself holds on a clean pool).
    let baseline = chaos_pool(None);
    let (base_ok, base_failed, base_corrupt, _, base_rps) = drive_chaos(&baseline, n);
    baseline.stop();
    assert_eq!(base_ok, n, "fault-free baseline must serve everything");
    assert_eq!((base_failed, base_corrupt), (0, 0));

    // Fault cell: transient errors + silent corruption against the
    // deployed config for the middle-sixth of the run. Quarantine must
    // trip promptly, route around the poisoned variant, probe it after
    // cooloff, and restore it once the fault window closes — recovering
    // final-third goodput.
    let onset = (n / 6) as u64;
    let plan = FaultPlan {
        seed: 11,
        onset,
        fault_until: (n / 3) as u64,
        transient_permille: 700,
        corrupt_permille: 250,
        target_config: Some(best),
        ..FaultPlan::default()
    };
    let coord = chaos_pool(Some(plan));
    let (ok, failed, corrupt_ok, first_trip, final_rps) = drive_chaos(&coord, n);
    let report = coord.stop_detailed();
    let recovery = final_rps / base_rps.max(1e-9);
    let trip_latency = first_trip.map(|i| i.saturating_sub(onset as usize));
    if corrupt_ok > 0 {
        failures.push(format!(
            "chaos/fault: {corrupt_ok} corrupted results delivered as Ok (must be 0)"
        ));
    }
    if report.total.quarantine_trips == 0 {
        failures.push("chaos/fault: sustained targeted faults never tripped quarantine".into());
    }
    match trip_latency {
        Some(lat) if lat <= CHAOS_TRIP_WINDOW => {}
        Some(lat) => failures.push(format!(
            "chaos/fault: quarantine tripped {lat} requests after onset \
             (must be <= {CHAOS_TRIP_WINDOW})"
        )),
        None => failures
            .push("chaos/fault: quarantine never observed tripping mid-run".into()),
    }
    if report.total.quarantine_restores == 0 {
        failures.push(
            "chaos/fault: the variant was never restored after the fault window closed".into(),
        );
    }
    if recovery < CHAOS_RECOVERY_TOLERANCE {
        failures.push(format!(
            "chaos/fault: final-third goodput {recovery:.2}x the fault-free baseline \
             (must be >= {CHAOS_RECOVERY_TOLERANCE})"
        ));
    }
    let fault_cell = ChaosCell {
        scenario: "fault",
        requests: n,
        ok,
        failed,
        corrupt_ok,
        trips: report.total.quarantine_trips,
        probes: report.total.quarantine_probes,
        restores: report.total.quarantine_restores,
        respawns: report.total.worker_respawns,
        trip_latency,
        recovery_ratio: recovery,
    };

    // Panic cell: one seeded worker panic mid-run. The supervisor must
    // respawn the worker on its queue, and the panic may cost at most its
    // in-flight batch — every other request is served.
    let panic_n = (n / 4).max(48);
    let panic_plan = FaultPlan { seed: 13, panic_at: Some(40), ..FaultPlan::default() };
    let coord = chaos_pool(Some(panic_plan));
    let (pok, pfailed, pcorrupt, _, _) = drive_chaos(&coord, panic_n);
    let preport = coord.stop_detailed();
    let max_batch = kernelsel::coordinator::BatcherConfig::default().max_batch;
    if pcorrupt > 0 {
        failures.push(format!(
            "chaos/panic: {pcorrupt} corrupted results delivered as Ok (must be 0)"
        ));
    }
    if preport.total.worker_respawns == 0 {
        failures.push("chaos/panic: the dead worker was never respawned".into());
    }
    if pfailed > max_batch {
        failures.push(format!(
            "chaos/panic: {pfailed} requests lost to one panic \
             (must be <= the in-flight batch, {max_batch})"
        ));
    }
    if pok + pfailed != panic_n {
        failures.push(format!(
            "chaos/panic: {} responses for {panic_n} requests — a ticket hung",
            pok + pfailed
        ));
    }
    let panic_cell = ChaosCell {
        scenario: "panic",
        requests: panic_n,
        ok: pok,
        failed: pfailed,
        corrupt_ok: pcorrupt,
        trips: preport.total.quarantine_trips,
        probes: preport.total.quarantine_probes,
        restores: preport.total.quarantine_restores,
        respawns: preport.total.worker_respawns,
        trip_latency: None,
        recovery_ratio: 1.0,
    };
    vec![fault_cell, panic_cell]
}

fn chaos_to_json(cells: &[ChaosCell]) -> Json {
    Json::Arr(
        cells
            .iter()
            .map(|c| {
                let mut fields = vec![
                    ("scenario", Json::Str(c.scenario.to_string())),
                    ("requests", Json::Num(c.requests as f64)),
                    ("ok", Json::Num(c.ok as f64)),
                    ("failed", Json::Num(c.failed as f64)),
                    ("corrupt_ok", Json::Num(c.corrupt_ok as f64)),
                    ("trips", Json::Num(c.trips as f64)),
                    ("probes", Json::Num(c.probes as f64)),
                    ("restores", Json::Num(c.restores as f64)),
                    ("respawns", Json::Num(c.respawns as f64)),
                    ("recovery_ratio", Json::Num(c.recovery_ratio)),
                ];
                if let Some(lat) = c.trip_latency {
                    fields.push(("trip_latency", Json::Num(lat as f64)));
                }
                Json::obj(fields)
            })
            .collect(),
    )
}

fn cells_to_json(cells: &[Cell], mode: &str) -> Json {
    let entries: Vec<Json> = cells
        .iter()
        .map(|c| {
            let mut fields = vec![
                ("mix", Json::Str(c.mix.to_string())),
                ("routing", Json::Str(c.routing.to_string())),
                ("admission", Json::Str(c.admission.to_string())),
                ("shards", Json::Num(c.shards as f64)),
                ("requests", Json::Num(c.requests as f64)),
                ("throughput_rps", Json::Num(c.throughput_rps)),
                ("goodput_rps", Json::Num(c.goodput_rps)),
                ("p50_ms", Json::Num(c.p50_ms)),
                ("p99_ms", Json::Num(c.p99_ms)),
                ("spilled", Json::Num(c.spilled as f64)),
                ("steals", Json::Num(c.steals as f64)),
                ("rejected", Json::Num(c.rejected as f64)),
                ("shed", Json::Num(c.shed as f64)),
            ];
            if let Some(tenant) = c.tenant {
                fields.push(("tenant", Json::Str(tenant.to_string())));
            }
            Json::obj(fields)
        })
        .collect();
    Json::obj(vec![
        ("schema", Json::Str("kernelsel-bench-pool-v1".to_string())),
        ("mode", Json::Str(mode.to_string())),
        ("entries", Json::Arr(entries)),
    ])
}

/// Attach the optional `chaos` key (self-gating robustness cells; never
/// part of the throughput baseline comparison) to the bench document.
fn with_chaos(doc: Json, chaos: &[ChaosCell]) -> Json {
    let Json::Obj(mut fields) = doc else { return doc };
    fields.insert("chaos".to_string(), chaos_to_json(chaos));
    Json::Obj(fields)
}

/// Compare against a committed baseline; list every matching cell whose
/// throughput dropped below `REGRESSION_TOLERANCE x` baseline.
fn regressions(cells: &[Cell], baseline: &Json) -> Vec<String> {
    let mut out = Vec::new();
    let Some(entries) = baseline.get("entries").and_then(|e| e.as_arr()) else {
        out.push("baseline has no entries array".to_string());
        return out;
    };
    for b in entries {
        let (Some(mix), Some(routing), Some(shards), Some(rps)) = (
            b.get("mix").and_then(|v| v.as_str()),
            b.get("routing").and_then(|v| v.as_str()),
            b.get("shards").and_then(|v| v.as_usize()),
            b.get("throughput_rps").and_then(|v| v.as_f64()),
        ) else {
            continue;
        };
        if mix == "overload" || mix == "tenants" || mix == "chaos" || mix == "explore" {
            // Overload cells serve a deliberately tiny admitted subset —
            // their throughput is scheduler noise, not capacity — and the
            // bench already self-gates them on goodput vs Unbounded. Keep
            // them out of the 20% throughput gate even once a ratcheted
            // baseline carries them. The tenants cells likewise self-gate
            // (fair vs isolated goodput, quota-off must violate) and are
            // keyed per tenant, which this (mix, routing, shards,
            // admission) lookup can't distinguish. Chaos cells are
            // self-gating too (corruption/trip/recovery exit codes) and
            // deliberately run degraded — never throughput-comparable.
            // Explore cells self-gate on coverage + p99-vs-control.
            continue;
        }
        // Pre-admission baselines carry no "admission" key: they describe
        // unbounded cells.
        let admission = b
            .get("admission")
            .and_then(|v| v.as_str())
            .unwrap_or("unbounded");
        let Some(cell) = cells.iter().find(|c| {
            c.mix == mix && c.routing == routing && c.shards == shards && c.admission == admission
        }) else {
            println!(
                "  (baseline {mix}/{routing}/{shards}/{admission} not in this sweep — skipped)"
            );
            continue;
        };
        let floor = rps * REGRESSION_TOLERANCE;
        if cell.throughput_rps < floor {
            out.push(format!(
                "{mix}/{routing}/{shards} shards: {:.1} req/s < {:.1} \
                 (baseline {:.1} x {:.0}% tolerance)",
                cell.throughput_rps,
                floor,
                rps,
                REGRESSION_TOLERANCE * 100.0
            ));
        }
    }
    out
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let traced = args.iter().any(|a| a == "--trace");
    let json_path = flag_value(&args, "--json");
    let baseline_path = flag_value(&args, "--check-against");

    let (n, shard_counts): (usize, &[usize]) =
        if smoke { (200, &[1, 2, 4]) } else { (600, &[1, 2, 4, 8]) };
    let mode = if smoke { "smoke" } else { "full" };
    println!(
        "== coordinator_skew ({mode}): {n} reqs/cell, shards {shard_counts:?}, \
         sim backend ==\n"
    );

    let mut cells = Vec::new();
    for &(mix, hot_share) in &[("uniform", 0.0), ("skew90", 0.9)] {
        for &routing in &["affinity", "load-aware"] {
            for &shards in shard_counts {
                let cell = run_cell(mix, hot_share, routing, shards, n, traced);
                println!(
                    "{:>8} {:>10} {} shard(s): {:>8.1} req/s  p50 {:>7.2} ms  \
                     p99 {:>7.2} ms  spilled {:>4}  steals {:>3}",
                    cell.mix,
                    cell.routing,
                    cell.shards,
                    cell.throughput_rps,
                    cell.p50_ms,
                    cell.p99_ms,
                    cell.spilled,
                    cell.steals,
                );
                cells.push(cell);
            }
        }
        println!();
    }

    // Overload scenario: an instantaneous hot-shape burst far beyond what
    // the shards can serve inside any SLO window (>= 3x capacity), judged
    // on goodput. Budgets are on the load-gauge scale (devsim-priced cost
    // hints): the hot 128^3 dispatch prices at ~44k gauge-ns plus 20k
    // queued overhead, so a 384k deadline admits a ~5-deep backlog.
    let service = measure_service_secs();
    let slo_secs = service * SLO_SERVICE_MULTIPLE as f64;
    let overload_shards = 2usize;
    let overload_n = if smoke { 160 } else { 320 };
    let overload_policies: [(&'static str, AdmissionPolicy); 3] = [
        ("unbounded", AdmissionPolicy::Unbounded),
        (
            "bounded-queue",
            AdmissionPolicy::BoundedQueue { max_inflight: 12, max_queue_ns: 50_000_000 },
        ),
        ("deadline-shed", AdmissionPolicy::DeadlineShed { deadline_ns: 384_000 }),
    ];
    println!(
        "overload: {overload_n}-request instantaneous burst, SLO {:.2} ms \
         ({SLO_SERVICE_MULTIPLE}x warm service {:.2} ms)",
        slo_secs * 1e3,
        service * 1e3
    );
    for (name, policy) in overload_policies {
        let cell = run_overload_cell(name, policy, overload_shards, overload_n, slo_secs);
        println!(
            "{:>8} {:>14} {} shard(s): goodput {:>7.1} req/s  served {:>7.1} req/s  \
             p50(ok) {:>7.2} ms  p99(ok) {:>7.2} ms  rejected {:>4}  shed {:>3}",
            cell.mix,
            cell.admission,
            cell.shards,
            cell.goodput_rps,
            cell.throughput_rps,
            cell.p50_ms,
            cell.p99_ms,
            cell.rejected,
            cell.shed,
        );
        cells.push(cell);
    }
    println!();

    // Acceptance verdict: at the widest sweep point, load-aware must beat
    // pure affinity on the skewed mix (throughput and p99) and must not
    // regress the uniform mix.
    let widest = *shard_counts.last().unwrap();
    let find = |mix: &str, routing: &str| {
        cells
            .iter()
            .find(|c| c.mix == mix && c.routing == routing && c.shards == widest)
            .unwrap()
    };
    let (sa, sl) = (find("skew90", "affinity"), find("skew90", "load-aware"));
    let (ua, ul) = (find("uniform", "affinity"), find("uniform", "load-aware"));
    println!(
        "skew90 @ {widest} shards: load-aware {:.2}x throughput, p99 {:.2} -> {:.2} ms  [{}]",
        sl.throughput_rps / sa.throughput_rps,
        sa.p99_ms,
        sl.p99_ms,
        if sl.throughput_rps > sa.throughput_rps && sl.p99_ms < sa.p99_ms {
            "OK"
        } else {
            "NOT BEATING AFFINITY"
        }
    );
    println!(
        "uniform @ {widest} shards: load-aware {:.2}x throughput  [{}]",
        ul.throughput_rps / ua.throughput_rps,
        if ul.throughput_rps >= 0.9 * ua.throughput_rps { "OK" } else { "REGRESSION" }
    );
    let over = |admission: &str| {
        cells
            .iter()
            .find(|c| c.mix == "overload" && c.admission == admission)
            .unwrap()
    };
    let (ou, ob, od) = (over("unbounded"), over("bounded-queue"), over("deadline-shed"));
    println!(
        "overload @ {overload_shards} shards: goodput unbounded {:.1} / bounded-queue {:.1} / \
         deadline-shed {:.1} req/s; p99(ok) {:.1} / {:.1} / {:.1} ms  [{}]",
        ou.goodput_rps,
        ob.goodput_rps,
        od.goodput_rps,
        ou.p99_ms,
        ob.p99_ms,
        od.p99_ms,
        if ob.goodput_rps >= ou.goodput_rps
            && od.goodput_rps >= ou.goodput_rps
            && ob.p99_ms <= slo_secs * 1e3
            && od.p99_ms <= slo_secs * 1e3
        {
            "OK"
        } else {
            "SHEDDING NOT BEATING COLLAPSE"
        }
    );
    // Enforced (with runner-noise headroom): unlike the skew verdict,
    // the overload cells have no committed baseline backstopping them in
    // --check-against, so the acceptance criterion gates here. A policy
    // that served nothing has p50/p99 encoded as 0.0 (no data) — that
    // must fail the gate, never satisfy the p99 check vacuously.
    let goodput_floor = OVERLOAD_GATE_TOLERANCE * ou.goodput_rps;
    let healthy = |c: &Cell| {
        c.throughput_rps > 0.0 // served at least one response at all
            && c.goodput_rps >= goodput_floor
            && c.p99_ms <= slo_secs * 1e3
    };
    let overload_gate_failed = !healthy(ob) || !healthy(od);
    println!();

    // Adversarial-tenant fairness scenario: 3 paced in-quota tenants +
    // 1 hostile flood tenant, run three ways — isolated baseline (one
    // tenant alone), quotas on, quotas off. Judged on each in-quota
    // tenant's p99 vs the SLO and goodput vs its isolated-run goodput.
    let tenant_n = if smoke { 60 } else { 120 };
    let interval = Duration::from_secs_f64((4.0 * service).max(0.001));
    println!(
        "tenants: {} in-quota tenants paced at {:.2} ms/req ({tenant_n} reqs each) + \
         hostile flood @ 32-deep; SLO {:.2} ms, quota {} slots",
        IN_QUOTA_TENANTS.len(),
        interval.as_secs_f64() * 1e3,
        slo_secs * 1e3,
        TENANT_QUOTA_SLOTS,
    );
    let print_tenant = |c: &Cell| {
        println!(
            "{:>8} {:>14} {:<6}: goodput {:>6.1} req/s  served {:>6.1} req/s  \
             p50 {:>7.2} ms  p99 {:>7.2} ms  rejected {:>4}",
            c.mix,
            c.admission,
            c.tenant.unwrap_or("?"),
            c.goodput_rps,
            c.throughput_rps,
            c.p50_ms,
            c.p99_ms,
            c.rejected,
        );
    };
    let iso = run_isolated(tenant_n, interval, slo_secs);
    print_tenant(&iso);
    let fair = run_adversarial("quota-fair", TENANT_QUOTA_SLOTS, tenant_n, interval, slo_secs);
    for c in &fair {
        print_tenant(c);
    }
    let unfair = run_adversarial("quota-off", 0, tenant_n, interval, slo_secs);
    for c in &unfair {
        print_tenant(c);
    }
    // A tenant that served nothing has p99 encoded as 0.0 (no data); the
    // explicit > 0.0 check keeps that from passing the SLO vacuously.
    let tenant_goodput_floor = TENANT_ISOLATION_TOLERANCE * iso.goodput_rps;
    let isolated_ok = |c: &Cell| {
        c.p99_ms > 0.0 && c.p99_ms <= slo_secs * 1e3 && c.goodput_rps >= tenant_goodput_floor
    };
    let fair_holds = fair.iter().all(&isolated_ok);
    let unfair_violates = unfair.iter().any(|c| !isolated_ok(c));
    println!(
        "tenants @ quota-fair: every in-quota tenant in SLO with goodput >= \
         {:.0}% of isolated ({:.1} req/s)  [{}]",
        TENANT_ISOLATION_TOLERANCE * 100.0,
        iso.goodput_rps,
        if fair_holds { "OK" } else { "HOSTILE TENANT BROKE ISOLATION" }
    );
    println!(
        "tenants @ quota-off: same traffic without quotas violates isolation  [{}]",
        if unfair_violates { "OK (quotas are load-bearing)" } else { "CONTROL FAILED" }
    );
    let tenant_gate_failed = !fair_holds || !unfair_violates;
    cells.push(iso);
    cells.extend(fair);
    cells.extend(unfair);
    println!();

    // Chaos scenario: seeded faults against a live pool — transient +
    // corruption burst targeted at the deployed config, then a worker
    // panic. Entirely self-gating: trips must land promptly, no corrupt
    // result may ever surface as Ok, goodput must recover, a panic may
    // cost at most its in-flight batch.
    let chaos_n = if smoke { 240 } else { 360 };
    println!(
        "chaos: {chaos_n}-request sequential run, faults over [{}, {}), then a \
         seeded worker panic",
        chaos_n / 6,
        chaos_n / 3,
    );
    let mut chaos_failures = Vec::new();
    let chaos_cells = run_chaos_cells(chaos_n, &mut chaos_failures);
    for c in &chaos_cells {
        println!(
            "{:>8} {:>14}: ok {:>4}  failed {:>3}  corrupt-as-ok {}  trips {}  probes {}  \
             restores {}  respawns {}  trip-latency {}  recovery {:.2}x",
            "chaos",
            c.scenario,
            c.ok,
            c.failed,
            c.corrupt_ok,
            c.trips,
            c.probes,
            c.restores,
            c.respawns,
            c.trip_latency.map_or_else(|| "-".to_string(), |l| l.to_string()),
            c.recovery_ratio,
        );
    }
    println!(
        "chaos: quarantine + supervision recover the pool  [{}]",
        if chaos_failures.is_empty() { "OK" } else { "NOT SELF-HEALING" }
    );
    let chaos_gate_failed = !chaos_failures.is_empty();
    println!();

    // Exploration scenario: seeded epsilon probing must measure >= 90%
    // of the healthy shipped (bucket, config) matrix within a hard probe
    // budget, while traced e2e p99 stays within 10% of an identical
    // no-explore control run.
    let explore_n = if smoke { 220 } else { 330 };
    let explore_cfg = ExploreConfig {
        eps_permille: 1000,
        budget: EXPLORE_BUDGET,
        seed: 21,
        top_k: 3,
    };
    println!(
        "explore: {explore_n} sequential requests over the cheap buckets, eps 1000/1000, \
         budget {EXPLORE_BUDGET} probes, vs a no-explore control"
    );
    let (control_cell, _, _) = run_explore_cell("control", None, explore_n);
    let (explore_cell, coverage, explore_stats) =
        run_explore_cell("explore", Some(explore_cfg), explore_n);
    let (control_p99, explore_p99) = (control_cell.p99_ms, explore_cell.p99_ms);
    for c in [&control_cell, &explore_cell] {
        println!(
            "{:>8} {:>14} {} shard(s): {:>8.1} req/s  p50 {:>7.2} ms  p99 {:>7.2} ms",
            c.mix, c.admission, c.shards, c.throughput_rps, c.p50_ms, c.p99_ms,
        );
    }
    println!(
        "{:>8} {:>14}: probes issued {} / shed {} / completed {}, first-sight {} \
         bucket(s) / {} run(s)",
        "explore",
        "counters",
        explore_stats.probes_issued,
        explore_stats.probes_shed,
        explore_stats.probes_completed,
        explore_stats.first_sight_shapes,
        explore_stats.first_sight_runs,
    );
    let coverage_ok = coverage.0 as f64 >= EXPLORE_COVERAGE_MIN * coverage.1 as f64;
    let budget_ok = explore_stats.probes_issued <= EXPLORE_BUDGET;
    let p99_ok = explore_p99 <= control_p99 * EXPLORE_P99_TOLERANCE;
    println!(
        "explore: coverage {}/{} pairs ({:.0}% floor), {} probes within budget {}, \
         p99 {:.2} ms vs control {:.2} ms  [{}]",
        coverage.0,
        coverage.1,
        EXPLORE_COVERAGE_MIN * 100.0,
        explore_stats.probes_issued,
        EXPLORE_BUDGET,
        explore_p99,
        control_p99,
        if coverage_ok && budget_ok && p99_ok { "OK" } else { "EXPLORATION NOT EARNING KEEP" }
    );
    let explore_gate_failed = !(coverage_ok && budget_ok && p99_ok);
    cells.push(control_cell);
    cells.push(explore_cell);

    if let Some(path) = json_path {
        let doc = with_chaos(cells_to_json(&cells, mode), &chaos_cells);
        std::fs::write(&path, doc.to_string() + "\n").expect("write BENCH_pool.json");
        println!("\nwrote {path}");
    }

    if let Some(path) = baseline_path {
        match std::fs::read_to_string(&path) {
            Ok(text) => {
                let baseline = parse(&text).expect("parse baseline BENCH_pool.json");
                let regs = regressions(&cells, &baseline);
                if regs.is_empty() {
                    println!(
                        "no throughput regression vs {path} ({:.0}% floor kept)",
                        REGRESSION_TOLERANCE * 100.0
                    );
                } else {
                    eprintln!("\nTHROUGHPUT REGRESSIONS vs {path}:");
                    for r in &regs {
                        eprintln!("  {r}");
                    }
                    std::process::exit(1);
                }
            }
            Err(e) => {
                // First run on a branch with no committed baseline yet: the
                // gate records instead of failing.
                println!("no baseline at {path} ({e}); skipping regression check");
            }
        }
    }

    if overload_gate_failed {
        eprintln!(
            "\nOVERLOAD GATE FAILED: each shedding policy must hold goodput >= {:.0}% of \
             Unbounded's with p99(ok) inside the SLO (see the overload verdict line above)",
            OVERLOAD_GATE_TOLERANCE * 100.0
        );
        std::process::exit(1);
    }
    if tenant_gate_failed {
        eprintln!(
            "\nTENANT FAIRNESS GATE FAILED: with quotas on, every in-quota tenant must \
             stay in SLO at >= {:.0}% of isolated goodput under a hostile flood, AND the \
             quota-off control must violate that (see the tenants verdict lines above)",
            TENANT_ISOLATION_TOLERANCE * 100.0
        );
        std::process::exit(1);
    }
    if chaos_gate_failed {
        eprintln!("\nCHAOS GATE FAILED:");
        for f in &chaos_failures {
            eprintln!("  {f}");
        }
        std::process::exit(1);
    }
    if explore_gate_failed {
        eprintln!(
            "\nEXPLORE GATE FAILED: within a {EXPLORE_BUDGET}-probe budget the pool must \
             measure >= {:.0}% of the healthy shipped (bucket, config) matrix \
             (got {}/{}) with traced e2e p99 within {:.0}% of the no-explore control \
             ({explore_p99:.2} ms vs {control_p99:.2} ms)",
            EXPLORE_COVERAGE_MIN * 100.0,
            coverage.0,
            coverage.1,
            (EXPLORE_P99_TOLERANCE - 1.0) * 100.0,
        );
        std::process::exit(1);
    }
}
