//! Bench: scheduling under shape skew — the load-aware router + work
//! stealing pool vs the pure shape-affinity pool (PR-1 behavior: hash
//! routing, no spills, no steals), swept over shard counts on a uniform
//! and a 90/10-skewed shape mix.
//!
//! Each cell submits the whole workload asynchronously (open backlog, the
//! worst case for a pinned hot shape), then drains every response:
//! throughput is requests / makespan, latency percentiles come from the
//! per-request end-to-end latencies.
//!
//!     cargo bench --bench coordinator_skew
//!     cargo bench --bench coordinator_skew -- --smoke \
//!         --json BENCH_pool.json --check-against ci/BENCH_pool.json
//!
//! `--smoke` shrinks the sweep for CI. `--json PATH` writes the
//! machine-readable `BENCH_pool.json` (schema in ARCHITECTURE.md).
//! `--check-against PATH` compares throughput per (mix, routing, shards)
//! cell against a previously committed run and exits non-zero on a >20%
//! regression — the CI perf gate.

use std::path::PathBuf;
use std::time::Instant;

use kernelsel::coordinator::{Coordinator, PoolConfig, Routing, SelectorPolicy};
use kernelsel::dataset::GemmShape;
use kernelsel::util::json::{parse, Json};
use kernelsel::util::{fill_buffer, Stats};

/// Throughput may regress by at most this factor vs the committed baseline.
const REGRESSION_TOLERANCE: f64 = 0.80;

struct Cell {
    mix: &'static str,
    routing: &'static str,
    shards: usize,
    requests: usize,
    throughput_rps: f64,
    p50_ms: f64,
    p99_ms: f64,
    spilled: usize,
    steals: usize,
}

fn flag_value(args: &[String], name: &str) -> Option<String> {
    args.iter().position(|a| a == name).and_then(|i| args.get(i + 1)).cloned()
}

/// The request mix: `hot_share` of requests use the hot shape, the rest
/// cycle through the cold shapes. All shapes ship in both manifests.
fn workload(n: usize, hot_share: f64) -> Vec<GemmShape> {
    let hot = GemmShape::new(128, 128, 128, 1);
    let cold = [
        GemmShape::new(32, 32, 32, 1),
        GemmShape::new(64, 64, 64, 1),
        GemmShape::new(32, 32, 32, 4),
        GemmShape::new(64, 64, 64, 4),
    ];
    let period = 10usize;
    let hot_per_period = ((hot_share * period as f64).round() as usize).min(period);
    (0..n)
        .map(|i| {
            if i % period < hot_per_period {
                hot
            } else {
                cold[(i / period + i % period) % cold.len()]
            }
        })
        .collect()
}

/// Run one cell: async-submit the whole mix, drain everything, report.
fn run_cell(
    mix: &'static str,
    hot_share: f64,
    routing_name: &'static str,
    shards: usize,
    n: usize,
) -> Cell {
    let (routing, steal_min) = match routing_name {
        // PR-1 pure affinity: hash routing, stealing effectively disabled.
        "affinity" => (Routing::Affinity, usize::MAX),
        _ => (Routing::LoadAware, 2),
    };
    let coord = Coordinator::start_pool(
        PathBuf::from("artifacts"),
        SelectorPolicy::Xla,
        PoolConfig { shards, routing, steal_min, ..PoolConfig::default() },
    )
    .expect("start pool");

    let shapes = workload(n, hot_share);
    // Warm every executable cache so first-touch compiles stay out of the
    // measurement, then pre-generate inputs so the submit loop is tight.
    for s in [GemmShape::new(128, 128, 128, 1)]
        .iter()
        .chain(shapes.iter().take(40))
    {
        let lhs = fill_buffer(1, s.batch * s.m * s.k);
        let rhs = fill_buffer(2, s.batch * s.k * s.n);
        let _ = coord.call(*s, lhs, rhs);
    }
    let inputs: Vec<(GemmShape, Vec<f32>, Vec<f32>)> = shapes
        .iter()
        .enumerate()
        .map(|(i, s)| {
            (
                *s,
                fill_buffer(i as u32, s.batch * s.m * s.k),
                fill_buffer((i + 31) as u32, s.batch * s.k * s.n),
            )
        })
        .collect();

    let t0 = Instant::now();
    let rxs: Vec<_> = inputs
        .into_iter()
        .map(|(s, lhs, rhs)| coord.submit(s, lhs, rhs))
        .collect();
    let mut latencies = Vec::with_capacity(n);
    for rx in rxs {
        let resp = rx.recv().expect("response");
        assert!(resp.result.is_ok(), "{:?}", resp.result.err());
        latencies.push(resp.latency.as_secs_f64());
    }
    let wall = t0.elapsed().as_secs_f64();
    let report = coord.stop_detailed();
    let stats = Stats::from_secs(&latencies);
    Cell {
        mix,
        routing: routing_name,
        shards,
        requests: n,
        throughput_rps: n as f64 / wall,
        p50_ms: stats.p50 * 1e3,
        p99_ms: stats.p99 * 1e3,
        spilled: report.total.spilled,
        steals: report.total.steals,
    }
}

fn cells_to_json(cells: &[Cell], mode: &str) -> Json {
    let entries: Vec<Json> = cells
        .iter()
        .map(|c| {
            Json::obj(vec![
                ("mix", Json::Str(c.mix.to_string())),
                ("routing", Json::Str(c.routing.to_string())),
                ("shards", Json::Num(c.shards as f64)),
                ("requests", Json::Num(c.requests as f64)),
                ("throughput_rps", Json::Num(c.throughput_rps)),
                ("p50_ms", Json::Num(c.p50_ms)),
                ("p99_ms", Json::Num(c.p99_ms)),
                ("spilled", Json::Num(c.spilled as f64)),
                ("steals", Json::Num(c.steals as f64)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("schema", Json::Str("kernelsel-bench-pool-v1".to_string())),
        ("mode", Json::Str(mode.to_string())),
        ("entries", Json::Arr(entries)),
    ])
}

/// Compare against a committed baseline; list every matching cell whose
/// throughput dropped below `REGRESSION_TOLERANCE x` baseline.
fn regressions(cells: &[Cell], baseline: &Json) -> Vec<String> {
    let mut out = Vec::new();
    let Some(entries) = baseline.get("entries").and_then(|e| e.as_arr()) else {
        out.push("baseline has no entries array".to_string());
        return out;
    };
    for b in entries {
        let (Some(mix), Some(routing), Some(shards), Some(rps)) = (
            b.get("mix").and_then(|v| v.as_str()),
            b.get("routing").and_then(|v| v.as_str()),
            b.get("shards").and_then(|v| v.as_usize()),
            b.get("throughput_rps").and_then(|v| v.as_f64()),
        ) else {
            continue;
        };
        let Some(cell) = cells
            .iter()
            .find(|c| c.mix == mix && c.routing == routing && c.shards == shards)
        else {
            println!("  (baseline cell {mix}/{routing}/{shards} not in this sweep — skipped)");
            continue;
        };
        let floor = rps * REGRESSION_TOLERANCE;
        if cell.throughput_rps < floor {
            out.push(format!(
                "{mix}/{routing}/{shards} shards: {:.1} req/s < {:.1} \
                 (baseline {:.1} x {:.0}% tolerance)",
                cell.throughput_rps,
                floor,
                rps,
                REGRESSION_TOLERANCE * 100.0
            ));
        }
    }
    out
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let json_path = flag_value(&args, "--json");
    let baseline_path = flag_value(&args, "--check-against");

    let (n, shard_counts): (usize, &[usize]) =
        if smoke { (200, &[1, 2, 4]) } else { (600, &[1, 2, 4, 8]) };
    let mode = if smoke { "smoke" } else { "full" };
    println!(
        "== coordinator_skew ({mode}): {n} reqs/cell, shards {shard_counts:?}, \
         sim backend ==\n"
    );

    let mut cells = Vec::new();
    for &(mix, hot_share) in &[("uniform", 0.0), ("skew90", 0.9)] {
        for &routing in &["affinity", "load-aware"] {
            for &shards in shard_counts {
                let cell = run_cell(mix, hot_share, routing, shards, n);
                println!(
                    "{:>8} {:>10} {} shard(s): {:>8.1} req/s  p50 {:>7.2} ms  \
                     p99 {:>7.2} ms  spilled {:>4}  steals {:>3}",
                    cell.mix,
                    cell.routing,
                    cell.shards,
                    cell.throughput_rps,
                    cell.p50_ms,
                    cell.p99_ms,
                    cell.spilled,
                    cell.steals,
                );
                cells.push(cell);
            }
        }
        println!();
    }

    // Acceptance verdict: at the widest sweep point, load-aware must beat
    // pure affinity on the skewed mix (throughput and p99) and must not
    // regress the uniform mix.
    let widest = *shard_counts.last().unwrap();
    let find = |mix: &str, routing: &str| {
        cells
            .iter()
            .find(|c| c.mix == mix && c.routing == routing && c.shards == widest)
            .unwrap()
    };
    let (sa, sl) = (find("skew90", "affinity"), find("skew90", "load-aware"));
    let (ua, ul) = (find("uniform", "affinity"), find("uniform", "load-aware"));
    println!(
        "skew90 @ {widest} shards: load-aware {:.2}x throughput, p99 {:.2} -> {:.2} ms  [{}]",
        sl.throughput_rps / sa.throughput_rps,
        sa.p99_ms,
        sl.p99_ms,
        if sl.throughput_rps > sa.throughput_rps && sl.p99_ms < sa.p99_ms {
            "OK"
        } else {
            "NOT BEATING AFFINITY"
        }
    );
    println!(
        "uniform @ {widest} shards: load-aware {:.2}x throughput  [{}]",
        ul.throughput_rps / ua.throughput_rps,
        if ul.throughput_rps >= 0.9 * ua.throughput_rps { "OK" } else { "REGRESSION" }
    );

    if let Some(path) = json_path {
        let doc = cells_to_json(&cells, mode);
        std::fs::write(&path, doc.to_string() + "\n").expect("write BENCH_pool.json");
        println!("\nwrote {path}");
    }

    if let Some(path) = baseline_path {
        match std::fs::read_to_string(&path) {
            Ok(text) => {
                let baseline = parse(&text).expect("parse baseline BENCH_pool.json");
                let regs = regressions(&cells, &baseline);
                if regs.is_empty() {
                    println!(
                        "no throughput regression vs {path} ({:.0}% floor kept)",
                        REGRESSION_TOLERANCE * 100.0
                    );
                } else {
                    eprintln!("\nTHROUGHPUT REGRESSIONS vs {path}:");
                    for r in &regs {
                        eprintln!("  {r}");
                    }
                    std::process::exit(1);
                }
            }
            Err(e) => {
                // First run on a branch with no committed baseline yet: the
                // gate records instead of failing.
                println!("no baseline at {path} ({e}); skipping regression check");
            }
        }
    }
}
