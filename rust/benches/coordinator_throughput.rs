//! Bench: executor-pool scaling — multi-shard vs single-shard throughput on
//! a mixed-shape workload (the ISSUE-1 acceptance scenario).
//!
//! Eight client threads issue a five-bucket shape mix; the pool is swept
//! over shard counts. Because requests route by shape affinity, every
//! artifact's executable cache lives on exactly one shard at any width, so
//! scaling comes purely from parallel execution. Per-shard batch/fallback
//! metrics are reported at each shutdown.
//!
//!     cargo bench --bench coordinator_throughput

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

use kernelsel::classify::codegen::CompiledTree;
use kernelsel::classify::{ClassifierKind, KernelClassifier};
use kernelsel::coordinator::{Coordinator, PoolConfig, SelectorPolicy};
use kernelsel::dataset::{benchmark_shapes, config_by_name, GemmShape};
use kernelsel::devsim::{generate_dataset, profile_by_name};
use kernelsel::runtime::Manifest;
use kernelsel::util::fill_buffer;

const CLIENTS: usize = 8;
const REQUESTS_PER_CLIENT: usize = 8;

fn tuned_policy(manifest: &Manifest) -> SelectorPolicy {
    let ds = generate_dataset(
        profile_by_name("i7-6700k").unwrap(),
        &benchmark_shapes().into_iter().step_by(3).collect::<Vec<_>>(),
    );
    let deployed: Vec<usize> = manifest
        .deployed
        .iter()
        .map(|n| config_by_name(n).unwrap().index())
        .collect();
    let clf = KernelClassifier::fit(ClassifierKind::DecisionTreeB, &ds, &deployed, 7);
    SelectorPolicy::Tree(CompiledTree::compile(&clf).unwrap())
}

/// Run the mixed-shape workload on an N-shard pool; return req/s.
fn run_width(shards: usize, policy: SelectorPolicy) -> f64 {
    let coord = Arc::new(
        Coordinator::start_pool(
            PathBuf::from("artifacts"),
            policy,
            PoolConfig { shards, ..PoolConfig::default() },
        )
        .expect("start pool"),
    );
    let shapes = [
        GemmShape::new(128, 128, 128, 1),
        GemmShape::new(512, 784, 512, 1),
        GemmShape::new(64, 2304, 128, 1),
        GemmShape::new(1024, 27, 64, 1),
        GemmShape::new(256, 576, 128, 1),
    ];
    // Warm every executable cache so compile cost stays out of the sweep.
    for s in shapes {
        let lhs = fill_buffer(1, s.batch * s.m * s.k);
        let rhs = fill_buffer(2, s.batch * s.k * s.n);
        let _ = coord.call(s, lhs, rhs);
    }

    let t0 = Instant::now();
    let mut joins = Vec::new();
    for c in 0..CLIENTS {
        let coord = coord.clone();
        joins.push(std::thread::spawn(move || {
            for i in 0..REQUESTS_PER_CLIENT {
                let s = shapes[(c + i) % shapes.len()];
                let lhs = fill_buffer((c * 31 + i) as u32, s.batch * s.m * s.k);
                let rhs = fill_buffer((c * 31 + i + 17) as u32, s.batch * s.k * s.n);
                let resp = coord.call(s, lhs, rhs).expect("call");
                assert!(resp.result.is_ok(), "{:?}", resp.result.err());
            }
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    let wall = t0.elapsed().as_secs_f64();
    let total = CLIENTS * REQUESTS_PER_CLIENT;

    let report = Arc::try_unwrap(coord).ok().expect("sole owner").stop_detailed();
    let reqs = total as f64 / wall;
    println!("-- {shards} shard(s): {reqs:>8.1} req/s --");
    println!("{}", report.summary());
    reqs
}

fn main() {
    let manifest = Manifest::load_or_synthetic(&PathBuf::from("artifacts"));
    println!(
        "== executor-pool scaling ({CLIENTS} clients x {REQUESTS_PER_CLIENT} reqs, \
         tuned-tree policy, sim backend) ==\n"
    );
    let mut results = Vec::new();
    for shards in [1usize, 2, 4] {
        results.push((shards, run_width(shards, tuned_policy(&manifest))));
        println!();
    }
    let (_, single) = results[0];
    for &(shards, reqs) in &results[1..] {
        println!(
            "{shards} shards vs 1: {:.2}x throughput{}",
            reqs / single,
            if reqs >= single { "" } else { "  (REGRESSION)" }
        );
    }
}
