//! Bench: the native CPU GEMM variant family on real hardware — does
//! kernel selection earn its keep when the timings are measured, not
//! simulated?
//!
//! Three acceptance gates, all exit-code enforced:
//!
//! 1. **Variant spread** — in every shape regime (small / skinny / large)
//!    at least one grid cell must show the best variant >= 2x the worst:
//!    if every variant performs the same, selection has nothing to earn.
//! 2. **Selection regret** — a selector tuned on the collected dataset
//!    (PCA+K-means deployment, exact-fit decision tree; k swept over a
//!    small range) must achieve >= 85% of the oracle-best variant's
//!    throughput, as a geometric mean across the grid.
//! 3. **Warm start** — the measured grid, re-recorded as probe
//!    provenance and round-tripped through the `kernelsel-telemetry-v1`
//!    wire format into a fresh default sink (what `serve
//!    --telemetry-out` / `--telemetry-in` does across a redeployment),
//!    must leave zero unmeasured (shape, variant) cells — so an
//!    exploration planner warm-started from the snapshot issues zero
//!    live probes — and a selector tuned on the restored data alone
//!    must reach >= 95% of the directly tuned selector's
//!    geomean-of-oracle, evaluated against the original measurements.
//!
//!     cargo bench --bench cpu_gemm
//!     cargo bench --bench cpu_gemm -- --smoke --json BENCH_cpu.json \
//!         --check-against ci/BENCH_cpu.json
//!
//! `--smoke` shrinks the grid and rep count for CI. `--json PATH` writes
//! the machine-readable `BENCH_cpu.json` (schema `kernelsel-bench-cpu-v1`,
//! documented in ARCHITECTURE.md). `--threads N` caps the worker budget
//! for the thread-parallel variants; `--reps N` sets best-of-N timing.
//! `--check-against PATH` compares `regret_geomean` and each regime's
//! `max_spread` against a previously committed run (the measured baseline
//! maintained by `tools/ratchet_baseline.py`) and exits non-zero on a
//! >20% drop — the mirror of the pool bench's throughput gate.

use kernelsel::classify::ClassifierKind;
use kernelsel::coordinator::cache::CostModel;
use kernelsel::coordinator::tune_selector_with;
use kernelsel::dataset::Normalization;
use kernelsel::engine::cpu::{collect_dataset, grid_cells, variant_by_index, GridCell};
use kernelsel::selection::Method;
use kernelsel::tuning::{live_dataset, DriftReport, TelemetrySink, TelemetrySnapshot};
use kernelsel::util::json::{parse, Json};

/// Gate 1: best/worst variant ratio required on >= 1 cell per regime.
const SPREAD_MIN: f64 = 2.0;

/// Gate 2: geomean of (chosen / oracle-best) throughput across the grid.
const REGRET_MIN: f64 = 0.85;

/// Gate 3: the selector tuned purely on the round-tripped warm-start
/// snapshot must reach this fraction of the directly tuned selector's
/// geomean-of-oracle (and the restored coverage must need zero probes).
const WARM_START_MIN: f64 = 0.95;

/// Samples recorded per warm-start cell — the pool sink's default
/// `min_samples` threshold, so the restored cells price immediately.
const WARM_START_SAMPLES: usize = 3;

/// Deployment sizes swept for the selection-regret gate.
const K_SWEEP: [usize; 3] = [4, 6, 8];

/// `--check-against`: regret geomean and per-regime spread may drop by at
/// most this factor vs the committed baseline (same tolerance as the pool
/// bench's throughput gate).
const BASELINE_TOLERANCE: f64 = 0.80;

/// Compare this run's headline metrics against a committed baseline doc;
/// returns one line per metric that fell below `BASELINE_TOLERANCE x`.
fn baseline_regressions(
    baseline: &Json,
    regret_geomean: f64,
    regimes: &[(&'static str, f64)],
) -> Vec<String> {
    let mut out = Vec::new();
    match baseline.get("regret_geomean").and_then(|v| v.as_f64()) {
        Some(base) => {
            let floor = base * BASELINE_TOLERANCE;
            if regret_geomean < floor {
                out.push(format!(
                    "regret_geomean: {:.1}% < {:.1}% (baseline {:.1}% x {:.0}% tolerance)",
                    regret_geomean * 100.0,
                    floor * 100.0,
                    base * 100.0,
                    BASELINE_TOLERANCE * 100.0
                ));
            }
        }
        None => out.push("baseline has no regret_geomean".to_string()),
    }
    let Some(entries) = baseline.get("regimes").and_then(|e| e.as_arr()) else {
        out.push("baseline has no regimes array".to_string());
        return out;
    };
    for b in entries {
        let (Some(regime), Some(base)) = (
            b.get("regime").and_then(|v| v.as_str()),
            b.get("max_spread").and_then(|v| v.as_f64()),
        ) else {
            continue;
        };
        let Some((_, got)) = regimes.iter().find(|(name, _)| *name == regime) else {
            println!("  (baseline regime {regime} not in this grid — skipped)");
            continue;
        };
        let floor = base * BASELINE_TOLERANCE;
        if *got < floor {
            out.push(format!(
                "{regime} max_spread: {got:.2}x < {floor:.2}x \
                 (baseline {base:.2}x x {:.0}% tolerance)",
                BASELINE_TOLERANCE * 100.0
            ));
        }
    }
    out
}

fn flag_value(args: &[String], name: &str) -> Option<String> {
    args.iter().position(|a| a == name).and_then(|i| args.get(i + 1)).cloned()
}

fn variant_name(index: usize) -> String {
    variant_by_index(index).map_or_else(|| format!("cfg{index}"), |v| v.name())
}

struct CellReport {
    cell: GridCell,
    best_index: usize,
    best_gflops: f64,
    worst_index: usize,
    worst_gflops: f64,
    spread: f64,
    chosen_index: usize,
    chosen_gflops: f64,
    ratio: f64,
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let json_path = flag_value(&args, "--json");
    let baseline_path = flag_value(&args, "--check-against");
    let threads = flag_value(&args, "--threads")
        .and_then(|v| v.parse::<usize>().ok())
        .unwrap_or_else(|| {
            std::thread::available_parallelism().map_or(2, |n| n.get()).min(4)
        });
    let reps = flag_value(&args, "--reps")
        .and_then(|v| v.parse::<usize>().ok())
        .unwrap_or(if smoke { 2 } else { 3 });
    let mode = if smoke { "smoke" } else { "full" };

    let cells = grid_cells(smoke);
    println!(
        "== cpu_gemm ({mode}): {} grid cells, {} threads, best-of-{reps} timing ==\n",
        cells.len(),
        threads
    );

    // Collect the real PerfDataset: every variant timed on every cell.
    let ds = collect_dataset(&cells, threads, reps);

    // Tune on the measured data, sweeping the deployment size; keep the
    // k whose tree achieves the best geomean ratio vs the oracle.
    let variant_count = kernelsel::engine::cpu::NUM_CPU_VARIANTS;
    let mut best_k = K_SWEEP[0];
    let mut best_geomean = 0.0f64;
    let mut best_choices: Vec<usize> = Vec::new();
    for k in K_SWEEP {
        let Some((_deployed, tree)) = tune_selector_with(
            Method::PcaKMeans,
            ClassifierKind::DecisionTreeA,
            &ds,
            k,
            Normalization::Standard,
            7,
        ) else {
            continue;
        };
        let choices: Vec<usize> =
            ds.shapes.iter().map(|s| tree.predict_config(&s.features())).collect();
        let mut log_sum = 0.0f64;
        for (i, &chosen) in choices.iter().enumerate() {
            let oracle = (0..variant_count)
                .map(|v| ds.gflops[(i, v)])
                .fold(0.0f64, f64::max);
            let got = ds.gflops[(i, chosen)];
            log_sum += (got.max(1e-12) / oracle.max(1e-12)).ln();
        }
        let geomean = (log_sum / choices.len() as f64).exp();
        println!("k={k}: selection geomean {:.1}% of oracle", geomean * 100.0);
        if geomean > best_geomean {
            best_geomean = geomean;
            best_k = k;
            best_choices = choices;
        }
    }

    // Per-cell report under the winning k.
    let mut reports: Vec<CellReport> = Vec::with_capacity(cells.len());
    for (i, cell) in cells.iter().enumerate() {
        let mut best_index = 0usize;
        let mut worst_index = 0usize;
        for v in 0..variant_count {
            if ds.gflops[(i, v)] > ds.gflops[(i, best_index)] {
                best_index = v;
            }
            if ds.gflops[(i, v)] < ds.gflops[(i, worst_index)] {
                worst_index = v;
            }
        }
        let best_gflops = ds.gflops[(i, best_index)];
        let worst_gflops = ds.gflops[(i, worst_index)];
        let chosen_index = best_choices.get(i).copied().unwrap_or(best_index);
        let chosen_gflops = ds.gflops[(i, chosen_index)];
        reports.push(CellReport {
            cell: *cell,
            best_index,
            best_gflops,
            worst_index,
            worst_gflops,
            spread: if worst_gflops > 0.0 { best_gflops / worst_gflops } else { 0.0 },
            chosen_index,
            chosen_gflops,
            ratio: if best_gflops > 0.0 { chosen_gflops / best_gflops } else { 0.0 },
        });
    }

    println!();
    for r in &reports {
        let s = r.cell.shape;
        println!(
            "{:>6} {:>4}x{:>4}x{:>4}b{}: best {:>22} {:>7.2} GF/s  worst {:>22} \
             {:>6.2} GF/s  spread {:>5.2}x  chosen {:>22} ({:>5.1}% of best)",
            r.cell.regime,
            s.m,
            s.k,
            s.n,
            s.batch,
            variant_name(r.best_index),
            r.best_gflops,
            variant_name(r.worst_index),
            r.worst_gflops,
            r.spread,
            variant_name(r.chosen_index),
            r.ratio * 100.0,
        );
    }

    // Gate 1: spread per regime.
    let mut regimes: Vec<(&'static str, f64)> = Vec::new();
    for r in &reports {
        match regimes.iter_mut().find(|(name, _)| *name == r.cell.regime) {
            Some((_, max)) => *max = max.max(r.spread),
            None => regimes.push((r.cell.regime, r.spread)),
        }
    }
    println!();
    let mut spread_failed = false;
    for (regime, max_spread) in &regimes {
        let ok = *max_spread >= SPREAD_MIN;
        println!(
            "{regime}: max best/worst spread {max_spread:.2}x  [{}]",
            if ok { "OK" } else { "BELOW GATE" }
        );
        spread_failed |= !ok;
    }

    // Gate 2: selection regret.
    let regret_ok = best_geomean >= REGRET_MIN;
    println!(
        "selection (k={best_k}): geomean {:.1}% of oracle-best  [{}]",
        best_geomean * 100.0,
        if regret_ok { "OK" } else { "BELOW GATE" }
    );

    // Gate 3: warm start. Re-record every measured cell as probe
    // provenance, round-trip through the wire format into a fresh
    // default sink, and tune a selector from the restored data alone —
    // the exploration-then-redeploy lifecycle, compressed into-process.
    let sink = TelemetrySink::new(WARM_START_SAMPLES as u64, 0.25);
    for (i, shape) in ds.shapes.iter().enumerate() {
        for v in 0..variant_count {
            let gf = ds.gflops[(i, v)];
            if gf <= 0.0 {
                continue;
            }
            let secs = shape.flops() / (gf * 1e9);
            for _ in 0..WARM_START_SAMPLES {
                sink.record_probe(*shape, Some(v), secs);
            }
        }
    }
    let wire = sink.snapshot().to_json().to_string();
    let restored = TelemetrySnapshot::from_json(&parse(&wire).expect("snapshot wire parses"))
        .expect("snapshot wire loads");
    let fresh = TelemetrySink::new(WARM_START_SAMPLES as u64, 0.25);
    fresh.absorb(&restored);
    // Zero-probe claim: every (shape, variant) cell prices from the
    // restored snapshot, so `unmeasured_candidates` is empty everywhere
    // and a warm-started exploration planner has nothing left to probe.
    let mut unmeasured = 0usize;
    for (i, shape) in ds.shapes.iter().enumerate() {
        for v in 0..variant_count {
            if ds.gflops[(i, v)] > 0.0 && fresh.measured_cost_secs(shape, Some(v)).is_none() {
                unmeasured += 1;
            }
        }
    }
    let pool: Vec<usize> = (0..variant_count).collect();
    let warm_ds = live_dataset(
        &fresh.snapshot(),
        &CostModel::CpuAnalytic,
        &DriftReport::default(),
        &pool,
        WARM_START_SAMPLES as u64,
    )
    .expect("restored snapshot folds into a live dataset");
    let mut warm_geomean = 0.0f64;
    for k in K_SWEEP {
        let Some((_deployed, tree)) = tune_selector_with(
            Method::PcaKMeans,
            ClassifierKind::DecisionTreeA,
            &warm_ds,
            k,
            Normalization::Standard,
            7,
        ) else {
            continue;
        };
        // Score the warm-tuned tree's choices against the ORIGINAL
        // measured grid — the regret a warm-started deployment actually
        // pays on live traffic.
        let mut log_sum = 0.0f64;
        for (i, shape) in ds.shapes.iter().enumerate() {
            let chosen = tree.predict_config(&shape.features());
            let oracle =
                (0..variant_count).map(|v| ds.gflops[(i, v)]).fold(0.0f64, f64::max);
            let got = ds.gflops[(i, chosen)];
            log_sum += (got.max(1e-12) / oracle.max(1e-12)).ln();
        }
        warm_geomean = warm_geomean.max((log_sum / ds.shapes.len() as f64).exp());
    }
    let warm_ratio = if best_geomean > 0.0 { warm_geomean / best_geomean } else { 0.0 };
    let warm_ok = unmeasured == 0 && warm_ratio >= WARM_START_MIN;
    println!(
        "warm start: {unmeasured} unmeasured cell(s) after round-trip; restored-data \
         selector geomean {:.1}% of oracle = {:.1}% of the directly tuned selector  [{}]",
        warm_geomean * 100.0,
        warm_ratio * 100.0,
        if warm_ok { "OK" } else { "BELOW GATE" }
    );

    if let Some(path) = json_path {
        let entries: Vec<Json> = reports
            .iter()
            .map(|r| {
                let s = r.cell.shape;
                Json::obj(vec![
                    ("regime", Json::Str(r.cell.regime.to_string())),
                    ("m", Json::Num(s.m as f64)),
                    ("k", Json::Num(s.k as f64)),
                    ("n", Json::Num(s.n as f64)),
                    ("batch", Json::Num(s.batch as f64)),
                    ("best_variant", Json::Str(variant_name(r.best_index))),
                    ("best_gflops", Json::Num(r.best_gflops)),
                    ("worst_variant", Json::Str(variant_name(r.worst_index))),
                    ("worst_gflops", Json::Num(r.worst_gflops)),
                    ("spread", Json::Num(r.spread)),
                    ("chosen_variant", Json::Str(variant_name(r.chosen_index))),
                    ("chosen_gflops", Json::Num(r.chosen_gflops)),
                    ("ratio_to_best", Json::Num(r.ratio)),
                ])
            })
            .collect();
        let regime_entries: Vec<Json> = regimes
            .iter()
            .map(|(name, max_spread)| {
                Json::obj(vec![
                    ("regime", Json::Str(name.to_string())),
                    ("max_spread", Json::Num(*max_spread)),
                ])
            })
            .collect();
        let doc = Json::obj(vec![
            ("schema", Json::Str("kernelsel-bench-cpu-v1".to_string())),
            ("mode", Json::Str(mode.to_string())),
            ("threads", Json::Num(threads as f64)),
            ("reps", Json::Num(reps as f64)),
            ("k_best", Json::Num(best_k as f64)),
            ("regret_geomean", Json::Num(best_geomean)),
            ("warm_start_geomean", Json::Num(warm_geomean)),
            ("warm_start_ratio", Json::Num(warm_ratio)),
            ("warm_start_unmeasured", Json::Num(unmeasured as f64)),
            ("entries", Json::Arr(entries)),
            ("regimes", Json::Arr(regime_entries)),
        ]);
        std::fs::write(&path, doc.to_string() + "\n").expect("write BENCH_cpu.json");
        println!("\nwrote {path}");
    }

    if let Some(path) = baseline_path {
        match std::fs::read_to_string(&path) {
            Ok(text) => {
                let baseline = parse(&text).expect("parse baseline BENCH_cpu.json");
                let regs = baseline_regressions(&baseline, best_geomean, &regimes);
                if regs.is_empty() {
                    println!(
                        "no regression vs {path} ({:.0}% floor kept)",
                        BASELINE_TOLERANCE * 100.0
                    );
                } else {
                    eprintln!("\nBASELINE REGRESSIONS vs {path}:");
                    for r in &regs {
                        eprintln!("  {r}");
                    }
                    std::process::exit(1);
                }
            }
            Err(e) => {
                // First run on a branch with no committed baseline yet: the
                // gate records instead of failing.
                println!("no baseline at {path} ({e}); skipping regression check");
            }
        }
    }

    if spread_failed {
        eprintln!(
            "\nSPREAD GATE FAILED: every regime needs >= 1 cell with best/worst >= \
             {SPREAD_MIN}x (see the per-regime lines above)"
        );
        std::process::exit(1);
    }
    if !regret_ok {
        eprintln!(
            "\nREGRET GATE FAILED: the tuned selector must achieve >= {:.0}% of the \
             oracle-best throughput geomean (got {:.1}%)",
            REGRET_MIN * 100.0,
            best_geomean * 100.0
        );
        std::process::exit(1);
    }
    if !warm_ok {
        eprintln!(
            "\nWARM START GATE FAILED: the round-tripped snapshot must leave zero \
             unmeasured cells (got {unmeasured}) and the restored-data selector must \
             reach >= {:.0}% of the directly tuned one (got {:.1}%)",
            WARM_START_MIN * 100.0,
            warm_ratio * 100.0
        );
        std::process::exit(1);
    }
}
