//! Bench: the submit hot path — striped resolution cache + pooled
//! completion slots vs the pre-fast-path dispatch machinery.
//!
//! The tentpole claim of the lock-light submit rework is that a warm
//! cache-hit dispatch costs a handful of atomics instead of a heap
//! allocation and a pool-global lock. This bench measures it two ways:
//!
//! * **dispatch cycle** (phase A): the per-request dispatch machinery in
//!   isolation, single- and multi-threaded. `baseline` reconstructs the
//!   pre-change path faithfully — resolve through a single
//!   `RwLock<HashMap>` (every submitter on one reader-count cache line)
//!   plus a fresh `mpsc::channel()` pair per request. `fastpath` is the
//!   shipped path — striped snapshot cache hit plus a pooled completion
//!   slot. Queue push, routing and input handling are identical in both
//!   designs and are deliberately excluded from both cells.
//! * **end-to-end** (phase B): `submit_many` against a live 2-shard
//!   SimBackend pool from 4 client threads — the CI throughput floor.
//!
//!     cargo bench --bench submit_hotpath
//!     cargo bench --bench submit_hotpath -- --smoke --json BENCH_hotpath.json \
//!         --min-ratio 1.5 --min-e2e-rps 2000
//!     cargo bench --bench submit_hotpath -- --smoke --trace
//!
//! `--min-ratio F` fails the run when the multi-threaded fastpath/baseline
//! ratio drops below `F`; `--min-e2e-rps F` is an absolute floor on the
//! phase-B request rate. The acceptance target for this rework is a >= 2x
//! multi-threaded dispatch-cycle ratio; CI gates at 1.5x to leave headroom
//! for throttled shared runners.
//!
//! `--trace` adds phase C, the flight-recorder overhead gate: phase B is
//! re-run (best of 3) with the recorder off and on, and the run fails
//! when the traced pool retains less than 95% of the untraced request
//! rate — tracing must stay cheap enough to leave on in production.

use std::collections::HashMap;
use std::hint::black_box;
use std::path::PathBuf;
use std::sync::mpsc;
use std::sync::{Arc, Barrier, RwLock};
use std::time::{Duration, Instant};

use kernelsel::coordinator::{
    Completion, CompletionPool, Coordinator, GemmResponse, KernelRegistry, PoolConfig,
    ResolutionCache, ResolvedKernel, SelectorPolicy, TraceConfig,
};
use kernelsel::dataset::GemmShape;
use kernelsel::runtime::Manifest;
use kernelsel::util::fill_buffer;
use kernelsel::util::json::Json;

/// One measured cell.
struct Cell {
    bench: &'static str,
    path: &'static str,
    threads: usize,
    ops_per_sec: f64,
}

/// Shared fixture for the dispatch-cycle cells.
struct Fixture {
    registry: KernelRegistry,
    cache: ResolutionCache,
    /// The pre-change design: one RwLock around one map.
    legacy: RwLock<HashMap<GemmShape, Arc<ResolvedKernel>>>,
    shapes: Vec<GemmShape>,
}

impl Fixture {
    fn new() -> Arc<Fixture> {
        let registry = KernelRegistry::new(Manifest::synthetic(), SelectorPolicy::Xla);
        let cache = ResolutionCache::new(1024);
        let shapes = registry.buckets();
        let mut legacy = HashMap::new();
        for shape in &shapes {
            let resolved = cache.resolve(&registry, shape).expect("bucket resolves");
            legacy.insert(*shape, resolved);
        }
        Arc::new(Fixture { registry, cache, legacy: RwLock::new(legacy), shapes })
    }

    /// Disjoint warm shape slice for one bench thread, so the striped
    /// cache's scaling (distinct stripes per thread) is actually exercised.
    fn shapes_for(&self, thread: usize, threads: usize) -> Vec<GemmShape> {
        let per = (self.shapes.len() / threads).max(1);
        let start = (thread * per) % self.shapes.len();
        (0..per).map(|i| self.shapes[(start + i) % self.shapes.len()]).collect()
    }
}

fn dummy_response(resolved: &ResolvedKernel) -> GemmResponse {
    GemmResponse {
        result: Ok(Vec::new()),
        config_used: resolved.meta.config_index,
        artifact: resolved.artifact().clone(),
        latency: Duration::ZERO,
    }
}

/// One pre-change dispatch cycle: single-lock map hit + fresh channel.
fn baseline_op(fixture: &Fixture, shape: &GemmShape) {
    let resolved = fixture.legacy.read().unwrap().get(shape).cloned().expect("warm legacy map");
    let cost = resolved.cost_hint_ns();
    let (tx, rx) = mpsc::channel();
    tx.send(dummy_response(&resolved)).expect("send");
    let resp = rx.recv().expect("recv");
    black_box(&resp);
    black_box(cost);
}

/// One shipped dispatch cycle: striped snapshot hit + pooled slot.
fn fastpath_op(fixture: &Fixture, completions: &Arc<CompletionPool>, shape: &GemmShape) {
    let resolved = fixture.cache.resolve(&fixture.registry, shape).expect("warm cache");
    let cost = fixture.cache.dispatch_cost_ns(&resolved);
    let (completion, ticket) =
        CompletionPool::checkout(completions).unwrap_or_else(Completion::oneshot);
    completion.complete(dummy_response(&resolved));
    let resp = ticket.wait();
    black_box(&resp);
    black_box(cost);
}

/// Run `iters_per_thread` dispatch cycles on each of `threads` threads,
/// returning aggregate ops/s. `fast` selects the measured path.
fn dispatch_cell(fixture: &Arc<Fixture>, threads: usize, iters: usize, fast: bool) -> Cell {
    let completions = CompletionPool::new(1024);
    let barrier = Arc::new(Barrier::new(threads + 1));
    let mut joins = Vec::with_capacity(threads);
    for t in 0..threads {
        let fixture = fixture.clone();
        let completions = completions.clone();
        let barrier = barrier.clone();
        joins.push(std::thread::spawn(move || {
            let shapes = fixture.shapes_for(t, threads);
            // Warmup outside the barrier: touch every shape on both paths.
            for shape in &shapes {
                if fast {
                    fastpath_op(&fixture, &completions, shape);
                } else {
                    baseline_op(&fixture, shape);
                }
            }
            barrier.wait();
            for i in 0..iters {
                let shape = &shapes[i % shapes.len()];
                if fast {
                    fastpath_op(&fixture, &completions, shape);
                } else {
                    baseline_op(&fixture, shape);
                }
            }
        }));
    }
    barrier.wait();
    let t0 = Instant::now();
    for join in joins {
        join.join().expect("bench thread");
    }
    let wall = t0.elapsed().as_secs_f64().max(1e-9);
    Cell {
        bench: "dispatch",
        path: if fast { "fastpath" } else { "baseline" },
        threads,
        ops_per_sec: (threads * iters) as f64 / wall,
    }
}

/// Phase B: `submit_many` runs of a warm hot shape against a live pool.
/// With `traced` the pool runs its flight recorder, sized so the whole
/// run fits the ring — the overhead measured is recording, not dropping.
fn e2e_cell(threads: usize, rounds: usize, batch: usize, traced: bool) -> Cell {
    // ~4 chain events per request (submit/route/execute/complete) plus
    // pool-level batch markers; the next power of two over the run keeps
    // every event recorded.
    let capacity = (threads * rounds * batch * 6).next_power_of_two();
    let coord = Arc::new(
        Coordinator::start_pool(
            PathBuf::from("artifacts"),
            SelectorPolicy::Xla,
            PoolConfig {
                shards: 2,
                trace: traced.then_some(TraceConfig { capacity, sample_every: 1 }),
                ..PoolConfig::default()
            },
        )
        .expect("start pool"),
    );
    let hot = GemmShape::new(32, 32, 32, 1);
    // Warm the executable cache, the resolution cache and the telemetry
    // cells so the measured region is pure steady state.
    for i in 0..8u32 {
        let lhs = fill_buffer(i, 32 * 32);
        let rhs = fill_buffer(i + 3, 32 * 32);
        coord.call(hot, lhs, rhs).expect("warm call").result.expect("warm gemm");
    }
    let barrier = Arc::new(Barrier::new(threads + 1));
    let mut joins = Vec::with_capacity(threads);
    for t in 0..threads {
        let coord = coord.clone();
        let barrier = barrier.clone();
        joins.push(std::thread::spawn(move || {
            barrier.wait();
            for round in 0..rounds {
                let requests: Vec<(GemmShape, Vec<f32>, Vec<f32>)> = (0..batch)
                    .map(|i| {
                        let seed = (t * 100_000 + round * 1000 + i) as u32;
                        (hot, fill_buffer(seed, 32 * 32), fill_buffer(seed + 7, 32 * 32))
                    })
                    .collect();
                for ticket in coord.submit_many(requests) {
                    ticket.wait().result.expect("gemm ok");
                }
            }
        }));
    }
    barrier.wait();
    let t0 = Instant::now();
    for join in joins {
        join.join().expect("client thread");
    }
    let wall = t0.elapsed().as_secs_f64().max(1e-9);
    let total = threads * rounds * batch;
    Arc::try_unwrap(coord).ok().expect("sole owner").stop();
    Cell {
        bench: "submit_many_e2e",
        path: if traced { "e2e_traced" } else { "e2e" },
        threads,
        ops_per_sec: total as f64 / wall,
    }
}

fn flag_value(args: &[String], name: &str) -> Option<String> {
    args.iter().position(|a| a == name).and_then(|i| args.get(i + 1)).cloned()
}

fn cells_to_json(cells: &[Cell], mode: &str) -> Json {
    let entries: Vec<Json> = cells
        .iter()
        .map(|c| {
            Json::obj(vec![
                ("bench", Json::Str(c.bench.to_string())),
                ("path", Json::Str(c.path.to_string())),
                ("threads", Json::Num(c.threads as f64)),
                ("ops_per_sec", Json::Num(c.ops_per_sec)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("schema", Json::Str("kernelsel-bench-hotpath-v1".to_string())),
        ("mode", Json::Str(mode.to_string())),
        ("entries", Json::Arr(entries)),
    ])
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let json_path = flag_value(&args, "--json");
    let min_ratio: Option<f64> = flag_value(&args, "--min-ratio").and_then(|v| v.parse().ok());
    let min_e2e_rps: Option<f64> = flag_value(&args, "--min-e2e-rps").and_then(|v| v.parse().ok());
    let trace_mode = args.iter().any(|a| a == "--trace");

    let (iters, rounds) = if smoke { (150_000, 8) } else { (600_000, 30) };
    let mode = if smoke { "smoke" } else { "full" };
    let mt = 4usize;
    println!("== submit_hotpath ({mode}): {iters} dispatch cycles/thread ==\n");

    let fixture = Fixture::new();
    let mut cells = Vec::new();
    for &threads in &[1usize, mt] {
        for &fast in &[false, true] {
            let cell = dispatch_cell(&fixture, threads, iters, fast);
            println!(
                "dispatch {:>9} {} thread(s): {:>12.0} ops/s",
                cell.path, cell.threads, cell.ops_per_sec
            );
            cells.push(cell);
        }
    }

    let find = |path: &str, threads: usize| {
        cells
            .iter()
            .find(|c| c.path == path && c.threads == threads)
            .map(|c| c.ops_per_sec)
            .unwrap_or(0.0)
    };
    let st_ratio = find("fastpath", 1) / find("baseline", 1).max(1e-9);
    let mt_ratio = find("fastpath", mt) / find("baseline", mt).max(1e-9);
    println!(
        "\nfastpath vs baseline: {st_ratio:.2}x single-threaded, {mt_ratio:.2}x at {mt} \
         threads  [{}]",
        if mt_ratio >= 2.0 { "OK, >= 2x target" } else { "BELOW the 2x target" }
    );

    let e2e = e2e_cell(mt, rounds, 32, false);
    let e2e_rps = e2e.ops_per_sec;
    println!(
        "\nsubmit_many end-to-end: {:.0} req/s ({} client threads, 2 shards, sim backend)",
        e2e.ops_per_sec, e2e.threads
    );
    cells.push(e2e);

    // Phase C (--trace): the recorder-overhead gate. Best of 3 per
    // setting — the max is the least-noisy estimate of what the path can
    // do on a shared runner.
    let mut trace_retained = None;
    if trace_mode {
        let best = |traced: bool| {
            (0..3)
                .map(|_| e2e_cell(mt, rounds, 32, traced).ops_per_sec)
                .fold(0.0f64, f64::max)
        };
        let off = best(false);
        let on = best(true);
        let retained = on / off.max(1e-9);
        println!(
            "\ntrace overhead: {off:.0} req/s recorder off, {on:.0} req/s on -> {:.1}% retained",
            retained * 100.0
        );
        cells.push(Cell {
            bench: "submit_many_e2e",
            path: "e2e_traced",
            threads: mt,
            ops_per_sec: on,
        });
        trace_retained = Some(retained);
    }

    if let Some(path) = json_path {
        let doc = cells_to_json(&cells, mode);
        std::fs::write(&path, doc.to_string() + "\n").expect("write BENCH_hotpath.json");
        println!("\nwrote {path}");
    }

    let mut failed = false;
    if let Some(floor) = min_ratio {
        if mt_ratio < floor {
            eprintln!(
                "FAIL: multi-threaded fastpath/baseline ratio {mt_ratio:.2}x < floor \
                 {floor:.2}x"
            );
            failed = true;
        }
    }
    if let Some(floor) = min_e2e_rps {
        if e2e_rps < floor {
            eprintln!("FAIL: end-to-end {e2e_rps:.0} req/s < floor {floor:.0} req/s");
            failed = true;
        }
    }
    if let Some(retained) = trace_retained {
        if retained < 0.95 {
            eprintln!(
                "FAIL: traced pool retains {:.1}% of untraced throughput (floor 95%)",
                retained * 100.0
            );
            failed = true;
        }
    }
    if failed {
        std::process::exit(1);
    }
}
