//! Bench: retune_convergence — the online-retuning acceptance proof.
//!
//! A selector tuned offline on the i7-6700k devsim profile serves a pool
//! whose backend simulates (and paces wall latency to) the R9 Nano — the
//! cross-device deployment the paper's "tuning for new hardware" story is
//! about — on a workload whose shape mix differs from the tuning set.
//! Measured-cost telemetry accumulates, then explicit retune cycles
//! (measure -> retune -> hot-swap) run until the selector stabilizes.
//!
//! Verdict: the post-swap selector must achieve **strictly better mean
//! latency** than the cold one on the same workload, the pool must report
//! `selector_swaps >= 1`, and the merged pool totals must equal the
//! per-shard sums.
//!
//!     cargo bench --bench retune_convergence

use std::path::PathBuf;
use std::time::Duration;

use kernelsel::classify::ClassifierKind;
use kernelsel::coordinator::{
    tune_selector_with, BatcherConfig, Coordinator, PoolConfig, SelectorPolicy,
};
use kernelsel::dataset::{benchmark_shapes, GemmShape, Normalization, PerfDataset};
use kernelsel::devsim::{generate_dataset, profile_by_name};
use kernelsel::engine::EngineKind;
use kernelsel::linalg::Matrix;
use kernelsel::runtime::Manifest;
use kernelsel::selection::Method;
use kernelsel::tuning::RetuneConfig;
use kernelsel::util::fill_buffer;

/// Wall-latency pacing: each execute sleeps 20x the simulated device time,
/// so selector quality dominates the (config-independent) host-GEMM cost.
const PACE_PERMILLE: u32 = 20_000;

/// Measurement rounds per phase (each round issues the whole mix).
const ROUNDS: usize = 4;

/// Retune cycles before giving up on convergence (typically ~6 suffice).
const MAX_CYCLES: usize = 16;

/// The serving mix: host-cheap buckets, weighted toward shapes where the
/// i7-tuned selector picks badly for the Nano — and deliberately different
/// from the (uniform) tuning-set distribution.
fn workload_mix() -> Vec<GemmShape> {
    let weighted: [(GemmShape, usize); 6] = [
        (GemmShape::new(32, 32, 32, 1), 6),
        (GemmShape::new(64, 64, 64, 1), 2),
        (GemmShape::new(32, 32, 32, 4), 2),
        (GemmShape::new(64, 64, 64, 4), 4),
        (GemmShape::new(128, 128, 128, 1), 2),
        (GemmShape::new(1024, 27, 64, 1), 2),
    ];
    let mut mix = Vec::new();
    for (shape, weight) in weighted {
        for _ in 0..weight {
            mix.push(shape);
        }
    }
    mix
}

/// Zero every column outside the shipped pool so selection can only pick
/// deployable kernels (mirrors what the online retuner's live dataset
/// does implicitly).
fn mask_to_pool(ds: &PerfDataset, pool: &[usize]) -> PerfDataset {
    let mut gflops = Matrix::zeros(ds.gflops.rows, ds.gflops.cols);
    for r in 0..ds.gflops.rows {
        for &c in pool {
            gflops[(r, c)] = ds.gflops[(r, c)];
        }
    }
    PerfDataset::new(&ds.device, ds.shapes.clone(), gflops)
}

/// Issue `rounds` full mixes of blocking requests; mean latency (seconds).
fn measure_phase(coord: &Coordinator, mix: &[GemmShape], rounds: usize, seed: u32) -> f64 {
    let mut total = 0.0f64;
    let mut n = 0usize;
    for round in 0..rounds {
        for (i, shape) in mix.iter().enumerate() {
            let s = seed + (round * mix.len() + i) as u32;
            let lhs = fill_buffer(s, shape.batch * shape.m * shape.k);
            let rhs = fill_buffer(s + 13, shape.batch * shape.k * shape.n);
            let resp = coord.call(*shape, lhs, rhs).expect("response");
            assert!(resp.result.is_ok(), "{:?}", resp.result.err());
            total += resp.latency.as_secs_f64();
            n += 1;
        }
    }
    total / n as f64
}

/// The selector's current pick per distinct mix shape.
fn current_picks(coord: &Coordinator, mix: &[GemmShape]) -> Vec<Option<usize>> {
    let policy = coord.registry().policy();
    let mut distinct = mix.to_vec();
    distinct.sort_by_key(|s| (s.m, s.k, s.n, s.batch));
    distinct.dedup();
    distinct.iter().map(|s| policy.policy.choose(s)).collect()
}

fn main() {
    println!("== retune_convergence: i7-tuned selector on a paced R9 Nano pool ==\n");

    // Cold deployment: the paper's offline pipeline on the *tuning*
    // device, restricted to the shipped artifact pool.
    let manifest = Manifest::synthetic();
    let pool_configs = manifest.shipped_configs();
    let tuning_profile = profile_by_name("i7-6700k").unwrap();
    let offline = generate_dataset(tuning_profile, &benchmark_shapes());
    let masked = mask_to_pool(&offline, &pool_configs);
    let (_, cold_tree) = tune_selector_with(
        Method::PcaKMeans,
        ClassifierKind::DecisionTreeB,
        &masked,
        pool_configs.len(),
        Normalization::Standard,
        7,
    )
    .expect("offline tree");

    let coord = Coordinator::start_pool(
        PathBuf::from("artifacts"),
        SelectorPolicy::Tree(cold_tree),
        PoolConfig {
            shards: 2,
            engine: EngineKind::SimPaced { profile: "r9-nano", permille: PACE_PERMILLE },
            // Hints/predictions priced on the device the selector was
            // tuned on — the serving device differing is the drift.
            pricing_profile: Some("i7-6700k"),
            // Single-request batches: latency must track per-dispatch
            // service time, not the batching wait budget.
            batcher: BatcherConfig { max_batch: 1, max_wait: Duration::ZERO },
            ..PoolConfig::default()
        },
    )
    .expect("coordinator start");

    let mix = workload_mix();
    // Warm every executable cache out of the measurement.
    let _ = measure_phase(&coord, &mix, 1, 900_000);

    let cold_mean = measure_phase(&coord, &mix, ROUNDS, 0);
    println!("cold (i7-tuned) mean latency: {:>8.2} ms", cold_mean * 1e3);

    // Measure -> retune -> hot-swap cycles until the selector stabilizes.
    let retune_cfg = RetuneConfig { min_cell_samples: 2, ..RetuneConfig::default() };
    let mut picks = current_picks(&coord, &mix);
    let mut cycles = 0usize;
    for cycle in 1..=MAX_CYCLES {
        cycles = cycle;
        let outcome = coord.retune_now(&retune_cfg);
        let new_picks = current_picks(&coord, &mix);
        let changed = new_picks.iter().zip(&picks).filter(|(a, b)| a != b).count();
        println!(
            "cycle {cycle}: {outcome:?} — {changed} pick(s) changed, \
             generation {}",
            coord.selector_generation()
        );
        let stable = changed == 0;
        picks = new_picks;
        // Traffic under the new selector: measures the new picks so the
        // next retune judges them by truth instead of priors.
        let _ = measure_phase(&coord, &mix, 1, 10_000 + cycle as u32 * 100);
        if stable && cycle > 1 {
            break;
        }
    }

    let tuned_mean = measure_phase(&coord, &mix, ROUNDS, 500_000);
    println!("tuned (measured-data) mean latency: {:>8.2} ms", tuned_mean * 1e3);

    let report = coord.stop_detailed();
    println!(
        "\nconverged after {cycles} cycle(s): {:.2}x mean-latency improvement \
         ({:.2} ms -> {:.2} ms), swaps={} drift_trips={}",
        cold_mean / tuned_mean,
        cold_mean * 1e3,
        tuned_mean * 1e3,
        report.total.selector_swaps,
        report.total.drift_trips,
    );
    println!("{}", report.summary());

    // --- acceptance gates -------------------------------------------------
    assert!(
        tuned_mean < cold_mean,
        "post-swap selector must be strictly faster: tuned {:.3} ms vs cold {:.3} ms",
        tuned_mean * 1e3,
        cold_mean * 1e3
    );
    assert!(
        report.total.selector_swaps >= 1,
        "pool must report at least one hot swap"
    );
    // Merged pool totals equal the per-shard sums, field by field.
    let sum = |f: fn(&kernelsel::coordinator::Metrics) -> usize| -> usize {
        report.per_shard.iter().map(f).sum()
    };
    assert_eq!(report.total.requests, sum(|m| m.requests));
    assert_eq!(report.total.batches, sum(|m| m.batches));
    assert_eq!(report.total.failures, sum(|m| m.failures));
    assert_eq!(report.total.steals, sum(|m| m.steals));
    assert_eq!(report.total.stolen_requests, sum(|m| m.stolen_requests));
    println!("\nOK: post-swap selector strictly beats the cold one; totals exact");
}
