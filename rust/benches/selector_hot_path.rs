//! Bench: the runtime-selection hot path (paper §5's cost argument).
//!
//! The whole point of shipping a decision tree in the launcher is that the
//! per-request classification cost must be negligible next to the kernel
//! launch. This bench measures, per lookup:
//!   * raw feature computation from a GemmShape,
//!   * the compiled (flattened, destandardized) decision tree,
//!   * the boxed classifier objects (tree / kNN / SVM / forest / MLP) —
//!     the costly alternatives Tables 1/2 argue against deploying.

use std::hint::black_box;
use std::time::Instant;

use kernelsel::classify::codegen::CompiledTree;
use kernelsel::classify::{ClassifierKind, KernelClassifier, ALL_CLASSIFIERS};
use kernelsel::dataset::{benchmark_shapes, GemmShape, Normalization};
use kernelsel::devsim::{generate_dataset, profile_by_name};
use kernelsel::selection::{select, Method};

fn bench<F: FnMut()>(label: &str, iters: usize, mut f: F) {
    // Warmup.
    for _ in 0..iters / 10 + 1 {
        f();
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let ns = t0.elapsed().as_nanos() as f64 / iters as f64;
    println!("{label:<44} {ns:>12.1} ns/op");
}

fn main() {
    println!("== selector hot path ==");
    let shapes: Vec<GemmShape> = benchmark_shapes().into_iter().step_by(2).collect();
    let ds = generate_dataset(profile_by_name("i7-6700k").unwrap(), &shapes);
    let deployed = select(Method::PcaKMeans, &ds, Normalization::Standard, 8, 7);

    let probe = GemmShape::new(512, 784, 512, 1);
    bench("GemmShape::features", 1_000_000, || {
        black_box(black_box(&probe).features());
    });

    let clf = KernelClassifier::fit(ClassifierKind::DecisionTreeB, &ds, &deployed, 7);
    let tree = CompiledTree::compile(&clf).unwrap();
    let feats = probe.features();
    bench("CompiledTree::predict_config (hot path)", 1_000_000, || {
        black_box(tree.predict_config(black_box(&feats)));
    });
    bench("CompiledTree incl. feature computation", 1_000_000, || {
        black_box(tree.predict_config(&black_box(&probe).features()));
    });

    println!("\n== classifier objects (why trees win deployment) ==");
    for kind in ALL_CLASSIFIERS {
        let clf = KernelClassifier::fit(kind, &ds, &deployed, 7);
        let iters = match kind {
            ClassifierKind::NearestNeighbor1
            | ClassifierKind::NearestNeighbor3
            | ClassifierKind::NearestNeighbor7
            | ClassifierKind::RadialSvm
            | ClassifierKind::LinearSvm => 20_000,
            ClassifierKind::RandomForest | ClassifierKind::Mlp => 50_000,
            _ => 500_000,
        };
        bench(&format!("{}::predict", kind.name()), iters, || {
            black_box(clf.predict_config(black_box(&feats)));
        });
    }
}
