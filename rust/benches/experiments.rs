//! Bench: regenerate every paper figure/table (the per-table end-to-end
//! harness required by DESIGN.md §5), timing each driver.
//!
//! `cargo bench` runs this with a stride-2 dataset to stay quick; the full
//! unstrided regeneration is `make experiments` / `kernelsel experiment all`.

use std::path::PathBuf;
use std::time::Instant;

use kernelsel::experiments::{run, Context, ALL_EXPERIMENTS};

fn main() {
    let stride: usize = std::env::var("KERNELSEL_BENCH_STRIDE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(2);
    let ctx = Context::with_stride(7, stride);
    let artifacts = PathBuf::from("artifacts");
    println!("== paper experiment regeneration (stride {stride}) ==\n");
    let mut total = 0.0;
    for id in ALL_EXPERIMENTS {
        let t0 = Instant::now();
        match run(id, &ctx, &artifacts) {
            Ok(tables) => {
                let secs = t0.elapsed().as_secs_f64();
                total += secs;
                println!("[{id}] {} table(s) in {secs:.2}s", tables.len());
                for t in tables {
                    println!("{}", t.render());
                }
            }
            Err(e) => println!("[{id}] ERROR: {e}"),
        }
    }
    println!("total experiment regeneration: {total:.1}s");
}
