//! Bench: PJRT artifact execution — per-configuration GEMM wallclock on the
//! local CPU, compile cost, and host<->device transfer overhead.
//!
//! This is the *measured* counterpart of the devsim numbers: it times every
//! deployed Pallas configuration plus the XLA-dot backend on the shipped
//! quickstart/Fig-1 shapes, i.e. a real (if small) slice of the paper's
//! brute-force benchmark matrix.

use std::time::{Duration, Instant};

use kernelsel::dataset::config_by_name;
use kernelsel::runtime::{Manifest, Runtime};
use kernelsel::util::{fill_buffer, timing::measure};

fn main() {
    let dir = std::path::PathBuf::from("artifacts");
    let runtime = Runtime::new(&dir).expect("PJRT runtime");
    let manifest = Manifest::load(&dir).expect("run `make artifacts` first");

    let shapes: [(usize, usize, usize, usize); 3] =
        [(128, 128, 128, 1), (512, 784, 512, 1), (64, 2304, 128, 1)];

    let mut backends: Vec<(String, Option<usize>)> = vec![("xla".into(), None)];
    for name in &manifest.deployed {
        backends.push((name.clone(), Some(config_by_name(name).unwrap().index())));
    }

    println!(
        "{:<20} {:>22} {:>12} {:>12} {:>10}",
        "backend", "shape", "mean ms", "p95 ms", "GFLOP/s"
    );
    for (m, k, n, b) in shapes {
        let lhs = fill_buffer(1, b * m * k);
        let rhs = fill_buffer(2, b * k * n);
        let flops = 2.0 * (b * m * k * n) as f64;
        for (name, cfg) in &backends {
            let Some(meta) = manifest.find_matmul(*cfg, m, k, n, b) else {
                continue;
            };
            let exe = runtime.load(&meta.path).expect("compile");
            let stats = measure(
                || {
                    runtime
                        .execute_f32(&exe, &[(&lhs, &[b, m, k]), (&rhs, &[b, k, n])])
                        .expect("exec");
                },
                2,
                Duration::from_millis(400),
            );
            println!(
                "{:<20} {:>22} {:>12.3} {:>12.3} {:>10.2}",
                name,
                format!("m{m} k{k} n{n} b{b}"),
                stats.mean_ms(),
                stats.p95 * 1e3,
                flops / stats.mean / 1e9
            );
        }
    }

    // Compile + transfer overheads.
    println!("\n== overheads ==");
    let meta = manifest.find_matmul(None, 128, 128, 128, 1).unwrap();
    let t0 = Instant::now();
    let fresh = Runtime::new(&dir).unwrap();
    let _ = fresh.load(&meta.path).unwrap();
    println!("cold load+compile (128^3 xla): {:.1} ms", t0.elapsed().as_secs_f64() * 1e3);

    let data = fill_buffer(3, 512 * 784);
    let stats = measure(
        || {
            fresh.upload(&data, &[512, 784]).unwrap();
        },
        3,
        Duration::from_millis(200),
    );
    println!(
        "upload 512x784 f32 (1.5 MiB): {:.3} ms ({:.2} GB/s)",
        stats.mean_ms(),
        (512.0 * 784.0 * 4.0) / stats.mean / 1e9
    );

    let final_stats = runtime.stats();
    println!(
        "\nruntime totals: {} compiles {:.2}s, {} executions {:.2}s",
        final_stats.compiles,
        final_stats.compile_secs,
        final_stats.executions,
        final_stats.execute_secs
    );
}
