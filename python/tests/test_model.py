"""Layer-2 model tests: layer fns, im2col, network forward, weight generator."""

import numpy as np
import pytest

import jax.numpy as jnp

from compile import model as M
from compile.kernels import KernelConfig

CFG = KernelConfig(2, 2, 2, 8, 8)


def rand(shape, seed=0):
    return jnp.asarray(np.random.default_rng(seed).normal(size=shape).astype(np.float32))


# ---------------------------------------------------------------------------
# fill_buffer: golden values that the Rust util::fill mirror must also match.
# ---------------------------------------------------------------------------


def test_fill_buffer_golden():
    buf = M.fill_buffer(7, 4)
    # xorshift32 with state seeded at (7 * 2654435761) % 2^32.
    state = (7 * 2654435761) % 2**32
    want = []
    x = state
    for _ in range(4):
        x ^= (x << 13) & 0xFFFFFFFF
        x ^= x >> 17
        x ^= (x << 5) & 0xFFFFFFFF
        want.append(x / 2**32 - 0.5)
    np.testing.assert_allclose(buf, np.array(want, np.float32), rtol=0, atol=0)


def test_fill_buffer_range_and_determinism():
    a = M.fill_buffer(123, 1000)
    b = M.fill_buffer(123, 1000)
    np.testing.assert_array_equal(a, b)
    assert np.all(a >= -0.5) and np.all(a < 0.5)
    assert np.std(a) > 0.2  # roughly uniform
    c = M.fill_buffer(124, 1000)
    assert np.any(a != c)


def test_fill_buffer_zero_seed_fallback():
    # seed*2654435761 % 2^32 == 0 must not give a stuck xorshift state.
    buf = M.fill_buffer(0, 8)
    assert np.any(buf != buf[0])


# ---------------------------------------------------------------------------
# im2col.
# ---------------------------------------------------------------------------


def test_im2col_matches_conv():
    """im2col GEMM must equal jax's own convolution."""
    import jax

    x = rand((1, 6, 6, 3), seed=1)
    w_hwio = rand((3, 3, 3, 5), seed=2)
    want = jax.lax.conv_general_dilated(
        x,
        w_hwio,
        window_strides=(1, 1),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    patches = M.im2col_3x3(x)  # (1, 36, 27)
    w_mat = w_hwio.reshape(9 * 3, 5)
    got = (patches @ w_mat).reshape(1, 6, 6, 5)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5)


def test_im2col_shape():
    x = rand((1, 8, 8, 4))
    assert M.im2col_3x3(x).shape == (1, 64, 36)


def test_maxpool():
    x = jnp.arange(16.0).reshape(1, 4, 4, 1)
    out = M.maxpool_2x2(x)
    assert out.shape == (1, 2, 2, 1)
    np.testing.assert_array_equal(
        np.asarray(out[0, :, :, 0]), np.array([[5.0, 7.0], [13.0, 15.0]])
    )


# ---------------------------------------------------------------------------
# Layer specs.
# ---------------------------------------------------------------------------


def test_vgg16_layer_structure():
    layers = M.vgg16_layers(224)
    assert len(layers) == 16  # 13 conv + 3 fc
    convs = [l for l in layers if isinstance(l, M.ConvSpec)]
    fcs = [l for l in layers if isinstance(l, M.FcSpec)]
    assert len(convs) == 13 and len(fcs) == 3
    # Paper §6.2: GEMM inputs vary from 12544x64 ... 512x512 territory.
    assert convs[0].gemm_m == 224 * 224 and convs[0].gemm_k == 27
    assert convs[2].gemm_m == 112 * 112 and convs[2].gemm_n == 128
    assert convs[-1].gemm_k == 9 * 512 and convs[-1].gemm_n == 512
    assert fcs[0].k == 7 * 7 * 512 and fcs[0].n == 4096
    assert fcs[-1].n == 1000
    # Total ~138M parameters.
    params = sum(9 * c.cin * c.cout + c.cout for c in convs)
    params += sum(f.k * f.n + f.n for f in fcs)
    assert 137e6 < params < 139e6


def test_vgg16_tiny_structure():
    layers = M.network_layers("vgg16-tiny")
    assert len(layers) == 16
    assert layers[0].hw == 32
    assert layers[-1].n == 10
    # Spatial size reaches 1x1 after 5 pools.
    assert layers[12].out_hw == 1


def test_unknown_network_raises():
    with pytest.raises(KeyError):
        M.network_layers("resnet9000")


# ---------------------------------------------------------------------------
# Layer forward: pallas backend vs xla backend.
# ---------------------------------------------------------------------------


def test_conv_layer_pallas_vs_xla():
    spec = M.ConvSpec("c", hw=8, cin=3, cout=16, pool=True)
    x = rand((1, 8, 8, 3), seed=3)
    w = rand((27, 16), seed=4)
    b = rand((16,), seed=5)
    got = M.conv_layer_fn(spec, M.pallas_backend(CFG))(x, w, b)
    want = M.conv_layer_fn(spec, M.xla_backend())(x, w, b)
    assert got.shape == (1, 4, 4, 16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_fc_layer_pallas_vs_xla():
    spec = M.FcSpec("f", k=64, n=32, relu=True)
    x, w, b = rand((1, 64), seed=6), rand((64, 32), seed=7), rand((32,), seed=8)
    got = M.fc_layer_fn(spec, M.pallas_backend(CFG))(x, w, b)
    want = M.fc_layer_fn(spec, M.xla_backend())(x, w, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)
    assert np.all(np.asarray(got) >= 0)  # relu applied


def test_fc_layer_no_relu_can_be_negative():
    spec = M.FcSpec("f", k=32, n=16, relu=False)
    x, w, b = rand((1, 32), seed=9), rand((32, 16), seed=10), rand((16,), seed=11)
    out = np.asarray(M.fc_layer_fn(spec, M.xla_backend())(x, w, b))
    assert np.any(out < 0)


def test_network_forward_tiny_pallas_matches_xla():
    """Full vgg16-tiny forward: per-layer Pallas kernels vs XLA backend."""
    layers = M.network_layers("vgg16-tiny")
    image = jnp.asarray(
        M.fill_buffer(99, 32 * 32 * 3).reshape(1, 32, 32, 3)
    )
    got = M.network_forward(layers, image, lambda i, s: M.pallas_backend(CFG))
    want = M.network_forward(layers, image, lambda i, s: M.xla_backend())
    assert got.shape == (1, 10)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=5e-4, atol=5e-4
    )
    assert np.all(np.isfinite(np.asarray(got)))


def test_layer_input_specs_match_forward():
    for spec in M.network_layers("vgg16-tiny"):
        shapes = M.layer_input_specs(spec)
        assert len(shapes) == 3
        if isinstance(spec, M.ConvSpec):
            assert shapes[0].shape == (1, spec.hw, spec.hw, spec.cin)
            assert shapes[1].shape == (9 * spec.cin, spec.cout)
        else:
            assert shapes[0].shape == (1, spec.k)
