"""Pallas kernel vs pure-jnp oracle — the core L1 correctness signal.

Deterministic sweeps cover every tile combination and every work-group
pairing; hypothesis drives randomized shapes, dtypes and configurations.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import (
    NUM_CONFIGS,
    TILE_SIZES,
    WORKGROUPS,
    KernelConfig,
    batched_matmul,
    batched_matmul_ref,
    config_by_index,
    matmul,
    matmul_ref,
    padded_dims,
)

RNG = np.random.default_rng(1234)


def rand(shape, dtype=np.float32):
    return jnp.asarray(RNG.normal(size=shape).astype(dtype))


def assert_matches_ref(lhs, rhs, cfg, rtol=2e-5, atol=2e-5):
    got = batched_matmul(lhs, rhs, cfg)
    want = batched_matmul_ref(lhs, rhs)
    assert got.shape == want.shape
    assert got.dtype == want.dtype
    np.testing.assert_allclose(
        np.asarray(got, np.float32),
        np.asarray(want, np.float32),
        rtol=rtol,
        atol=atol,
        err_msg=f"config {cfg.name}",
    )


@pytest.mark.parametrize("r", TILE_SIZES)
@pytest.mark.parametrize("a", TILE_SIZES)
@pytest.mark.parametrize("c", TILE_SIZES)
def test_all_tile_combinations(r, a, c):
    cfg = KernelConfig(r, a, c, 8, 8)
    lhs, rhs = rand((2, 33, 65)), rand((2, 65, 17))
    assert_matches_ref(lhs, rhs, cfg)


@pytest.mark.parametrize("wg", WORKGROUPS, ids=lambda w: f"{w[0]}x{w[1]}")
def test_all_workgroups(wg):
    cfg = KernelConfig(2, 2, 2, *wg)
    lhs, rhs = rand((3, 40, 50)), rand((3, 50, 30))
    assert_matches_ref(lhs, rhs, cfg)


def test_exact_block_multiple_shapes():
    # No padding path: shapes already multiples of the block geometry.
    cfg = KernelConfig(4, 2, 4, 8, 8)  # bm=32, bn=32, kc=64
    lhs, rhs = rand((2, 64, 128)), rand((2, 128, 32))
    mp, kp, np_ = padded_dims(cfg, 64, 128, 32)
    assert (mp, kp, np_) == (64, 128, 32)
    assert_matches_ref(lhs, rhs, cfg)


def test_single_element_dims():
    cfg = KernelConfig(1, 1, 1, 8, 8)
    assert_matches_ref(rand((1, 1, 1)), rand((1, 1, 1)), cfg)


def test_tall_skinny():
    # The paper's pathological class: m=32, k large, n tiny.
    cfg = KernelConfig(1, 8, 1, 8, 8)
    lhs, rhs = rand((1, 32, 1234)), rand((1, 1234, 27))
    # Larger K accumulates more reduction-order noise.
    assert_matches_ref(lhs, rhs, cfg, rtol=1e-4, atol=1e-4)


def test_batch_dimension_independent():
    cfg = KernelConfig(2, 1, 2, 8, 16)
    lhs, rhs = rand((4, 24, 40)), rand((4, 40, 24))
    out = batched_matmul(lhs, rhs, cfg)
    for b in range(4):
        np.testing.assert_allclose(
            np.asarray(out[b]),
            np.asarray(matmul_ref(lhs[b], rhs[b])),
            rtol=2e-5,
            atol=2e-5,
        )


def test_unbatched_wrapper():
    cfg = KernelConfig(2, 2, 2, 8, 8)
    lhs, rhs = rand((30, 20)), rand((20, 10))
    np.testing.assert_allclose(
        np.asarray(matmul(lhs, rhs, cfg)),
        np.asarray(matmul_ref(lhs, rhs)),
        rtol=2e-5,
        atol=2e-5,
    )


def test_bfloat16():
    cfg = KernelConfig(4, 2, 4, 8, 8)
    lhs = rand((2, 32, 64)).astype(jnp.bfloat16)
    rhs = rand((2, 64, 32)).astype(jnp.bfloat16)
    got = batched_matmul(lhs, rhs, cfg)
    want = batched_matmul_ref(lhs, rhs)
    assert got.dtype == jnp.bfloat16
    np.testing.assert_allclose(
        np.asarray(got, np.float32),
        np.asarray(want, np.float32),
        rtol=2e-2,
        atol=2e-2,
    )


def test_zero_inputs_give_zero():
    cfg = KernelConfig(8, 8, 8, 16, 16)
    lhs = jnp.zeros((1, 100, 300), jnp.float32)
    rhs = jnp.zeros((1, 300, 50), jnp.float32)
    out = batched_matmul(lhs, rhs, cfg)
    assert not np.any(np.asarray(out))


def test_identity_rhs_is_identity():
    cfg = KernelConfig(2, 4, 2, 16, 8)
    lhs = rand((2, 48, 36))
    eye = jnp.tile(jnp.eye(36, dtype=jnp.float32)[None], (2, 1, 1))
    np.testing.assert_allclose(
        np.asarray(batched_matmul(lhs, eye, cfg)),
        np.asarray(lhs),
        rtol=1e-6,
        atol=1e-6,
    )


def test_shape_mismatch_raises():
    cfg = KernelConfig(1, 1, 1, 8, 8)
    with pytest.raises(ValueError):
        batched_matmul(rand((1, 4, 5)), rand((1, 6, 4)), cfg)
    with pytest.raises(ValueError):
        batched_matmul(rand((2, 4, 5)), rand((1, 5, 4)), cfg)
    with pytest.raises(ValueError):
        batched_matmul(rand((4, 5)), rand((5, 4)), cfg)


# ---------------------------------------------------------------------------
# Hypothesis sweeps: random configs x random shapes x dtypes.
# ---------------------------------------------------------------------------

shape_dims = st.tuples(
    st.integers(1, 3),    # batch
    st.integers(1, 48),   # m
    st.integers(1, 80),   # k
    st.integers(1, 48),   # n
)


@settings(max_examples=25, deadline=None)
@given(cfg_idx=st.integers(0, NUM_CONFIGS - 1), dims=shape_dims)
def test_random_config_random_shape(cfg_idx, dims):
    cfg = config_by_index(cfg_idx)
    b, m, k, n = dims
    rng = np.random.default_rng(cfg_idx * 1_000_003 + m * 997 + k * 31 + n)
    lhs = jnp.asarray(rng.normal(size=(b, m, k)).astype(np.float32))
    rhs = jnp.asarray(rng.normal(size=(b, k, n)).astype(np.float32))
    assert_matches_ref(lhs, rhs, cfg, rtol=3e-5, atol=3e-5)


@settings(max_examples=10, deadline=None)
@given(
    cfg_idx=st.integers(0, NUM_CONFIGS - 1),
    m=st.integers(1, 32),
    k=st.integers(1, 64),
    n=st.integers(1, 32),
)
def test_random_bf16(cfg_idx, m, k, n):
    cfg = config_by_index(cfg_idx)
    rng = np.random.default_rng(cfg_idx + m + k + n)
    lhs = jnp.asarray(rng.normal(size=(1, m, k))).astype(jnp.bfloat16)
    rhs = jnp.asarray(rng.normal(size=(1, k, n))).astype(jnp.bfloat16)
    got = batched_matmul(lhs, rhs, cfg)
    want = batched_matmul_ref(lhs, rhs)
    np.testing.assert_allclose(
        np.asarray(got, np.float32),
        np.asarray(want, np.float32),
        rtol=5e-2,
        atol=5e-2,
    )


def test_padded_dims_properties():
    for idx in range(0, NUM_CONFIGS, 17):
        cfg = config_by_index(idx)
        for m, k, n in [(1, 1, 1), (37, 100, 27), (512, 784, 512)]:
            mp, kp, np_ = padded_dims(cfg, m, k, n)
            assert mp >= m and kp >= k and np_ >= n
            assert mp % cfg.block_m == 0
            assert kp % cfg.k_chunk == 0
            assert np_ % cfg.block_n == 0
            assert mp - m < cfg.block_m
            assert kp - k < cfg.k_chunk
            assert np_ - n < cfg.block_n
