"""Tests for the kernel configuration space."""

import pytest

from compile.kernels import (
    NUM_CONFIGS,
    TILE_SIZES,
    WORKGROUPS,
    all_configs,
    config_by_index,
    config_by_name,
)
from compile.kernels.config import K_UNIT


def test_space_size():
    cfgs = all_configs()
    assert len(cfgs) == 640
    assert NUM_CONFIGS == 640
    assert len(set(c.name for c in cfgs)) == 640


def test_index_roundtrip():
    for i, cfg in enumerate(all_configs()):
        assert cfg.index() == i
        assert config_by_index(i) == cfg


def test_name_roundtrip():
    for cfg in all_configs()[::37]:
        assert config_by_name(cfg.name) == cfg
    with pytest.raises(KeyError):
        config_by_name("r3a1c1_wg8x8")


def test_workgroup_products_legal():
    # The paper's pairing rule: work-group product capped by driver limits
    # (largest deployed pairing is 256 work-items).
    for wr, wc in WORKGROUPS:
        assert 1 <= wr * wc <= 256


def test_block_geometry():
    for cfg in all_configs():
        assert cfg.block_m == cfg.acc_r * cfg.wg_r
        assert cfg.block_n == cfg.acc_c * cfg.wg_c
        assert cfg.k_chunk == cfg.acc_a * K_UNIT
        assert cfg.acc_r in TILE_SIZES
        assert cfg.acc_a in TILE_SIZES
        assert cfg.acc_c in TILE_SIZES


def test_vmem_estimate_monotone_in_a():
    # Deeper A pipelines strictly grow the VMEM working set.
    base = config_by_name("r4a1c4_wg8x8")
    deeper = config_by_name("r4a8c4_wg8x8")
    assert deeper.vmem_bytes() > base.vmem_bytes()
    assert deeper.k_chunk == 8 * base.k_chunk
