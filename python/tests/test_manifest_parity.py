"""Cross-language parity: the artifact manifest ties the Python and Rust
views of the configuration space together. These tests pin the contract the
Rust runtime relies on (config names <-> indices, shapes, flops)."""

import json
import os

import pytest

from compile.kernels import config_by_index, config_by_name

MANIFEST = os.path.join(
    os.path.dirname(__file__), "..", "..", "artifacts", "manifest.json"
)


@pytest.fixture(scope="module")
def manifest():
    if not os.path.exists(MANIFEST):
        pytest.skip("run `make artifacts` first")
    with open(MANIFEST) as f:
        return json.load(f)


def test_config_names_and_indices_consistent(manifest):
    checked = 0
    for a in manifest["artifacts"]:
        if a["config"] is None:
            assert a["config_index"] is None
            continue
        cfg = config_by_name(a["config"])
        assert cfg.index() == a["config_index"], a["path"]
        checked += 1
    assert checked > 50


def test_flops_match_dims(manifest):
    for a in manifest["artifacts"]:
        if a["kind"] == "matmul":
            assert a["flops"] == 2 * a["b"] * a["m"] * a["k"] * a["n"]


def test_matmul_input_shapes_consistent(manifest):
    for a in manifest["artifacts"]:
        if a["kind"] == "matmul":
            assert a["inputs"] == [
                [a["b"], a["m"], a["k"]],
                [a["b"], a["k"], a["n"]],
            ]
            assert a["output"] == [a["b"], a["m"], a["n"]]


def test_deployed_set_valid(manifest):
    deployed = manifest["meta"]["deployed"]
    assert len(deployed) == len(set(deployed)) == 8
    for name in deployed + [manifest["meta"]["single_best"]]:
        cfg = config_by_name(name)  # raises KeyError if invalid
        assert config_by_index(cfg.index()) == cfg


def test_all_artifact_files_exist(manifest):
    base = os.path.dirname(MANIFEST)
    for a in manifest["artifacts"]:
        assert os.path.exists(os.path.join(base, a["path"])), a["path"]
