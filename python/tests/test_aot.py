"""AOT pipeline tests: lowering produces loadable HLO text + sane manifest."""

import json
import os

import pytest

from compile import aot
from compile import model as M
from compile.kernels import KernelConfig, config_by_name


def test_lower_matmul_produces_hlo_text():
    cfg = KernelConfig(2, 2, 2, 8, 8)
    text = aot.lower_matmul(cfg, 1, 16, 32, 8)
    assert "HloModule" in text
    assert "ENTRY" in text
    # Output is a 1-tuple (return_tuple=True) of the (1,16,8) result.
    assert "f32[1,16,8]" in text


def test_lower_matmul_xla_backend():
    text = aot.lower_matmul(None, 1, 8, 8, 8)
    assert "HloModule" in text
    assert "dot" in text


def test_lower_layer_conv():
    spec = M.ConvSpec("c", hw=4, cin=2, cout=4, pool=True)
    text = aot.lower_layer(spec, KernelConfig(1, 1, 1, 8, 8))
    assert "HloModule" in text
    assert "f32[1,2,2,4]" in text  # pooled output shape


def test_serving_bucket_shapes_unique_and_cover_network():
    shapes = aot.serving_bucket_shapes("vgg16-tiny")
    assert len(shapes) == len(set(shapes))
    gemms = {
        (s.gemm_m, s.gemm_k, s.gemm_n, 1) for s in M.network_layers("vgg16-tiny")
    }
    assert gemms.issubset(set(shapes))


def test_fig1_shapes_match_paper():
    assert aot.FIG1_SHAPES[0] == (512, 784, 512, 16)
    assert aot.FIG1_SHAPES[1] == (512, 4608, 784, 1)
    assert aot.FIG1_SHAPES[2] == (32, 12321, 27, 1)


def test_default_deploy_file_valid():
    path = os.path.join(os.path.dirname(aot.__file__), "deploy_default.json")
    configs, single = aot.load_deploy(path)
    assert len(configs) == 8
    assert len({c.name for c in configs}) == 8
    assert single.name == "r4a8c4_wg16x16"


def test_bundle_emits_manifest(tmp_path):
    bundle = aot.Bundle(str(tmp_path), force=False)
    cfg = config_by_name("r1a1c1_wg8x8")
    bundle.add_matmul("matmul", cfg, 1, 8, 8, 8)
    bundle.add_matmul("matmul", cfg, 1, 8, 8, 8)  # duplicate: ignored
    bundle.add_matmul("matmul", None, 1, 8, 8, 8)
    bundle.write_manifest({"test": True})
    with open(tmp_path / "manifest.json") as f:
        manifest = json.load(f)
    assert len(manifest["artifacts"]) == 2
    entry = manifest["artifacts"][0]
    assert entry["kind"] == "matmul"
    assert entry["flops"] == 2 * 8 * 8 * 8
    assert entry["inputs"] == [[1, 8, 8], [1, 8, 8]]
    for e in manifest["artifacts"]:
        assert (tmp_path / e["path"]).exists()


def test_bundle_caches_existing(tmp_path):
    cfg = config_by_name("r1a1c1_wg8x8")
    b1 = aot.Bundle(str(tmp_path), force=False)
    b1.add_matmul("matmul", cfg, 1, 8, 8, 8)
    assert b1.lowered == 1
    b2 = aot.Bundle(str(tmp_path), force=False)
    b2.add_matmul("matmul", cfg, 1, 8, 8, 8)
    assert b2.lowered == 0 and b2.skipped == 1
