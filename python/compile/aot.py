"""AOT pipeline: lower Layer-2 graphs to HLO-text artifacts for the Rust runtime.

HLO *text* (not ``.serialize()``) is the interchange format: jax >= 0.5 emits
HloModuleProtos with 64-bit instruction ids that the xla_extension 0.5.1
bundled with the ``xla`` crate rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Outputs under ``artifacts/``:

  matmul/…hlo.txt      standalone GEMM executables (logical shapes; padding
                       and result slicing are inside the HLO, so the Rust
                       side feeds plain (B,M,K)/(B,K,N) buffers),
  <network>/…hlo.txt   per-layer executables for every deployed kernel
                       configuration plus the ``xla`` comparator backend,
  collect/…hlo.txt     (opt-in) the full 640-configuration sweep used to
                       collect a measured-CPU dataset,
  manifest.json        metadata for every artifact (shapes, flops, configs).

Python runs once, at build time; the Rust binary is self-contained after
``make artifacts``.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels import (
    KernelConfig,
    all_configs,
    batched_matmul,
    config_by_name,
)

# ---------------------------------------------------------------------------
# Shape sets.
# ---------------------------------------------------------------------------

# Figure 1's three benchmark size sets (m, k, n, batch).
FIG1_SHAPES: List[Tuple[int, int, int, int]] = [
    (512, 784, 512, 16),
    (512, 4608, 784, 1),
    (32, 12321, 27, 1),
]

# Shapes used by the quickstart example, as (m, k, n, batch).
QUICKSTART_SHAPES = [(128, 128, 128, 1), (512, 784, 512, 1), (64, 2304, 128, 1)]

# Diverse shape set for measured-CPU data collection (batch 1 keeps a full
# 640-config sweep tractable on the CPU PJRT backend).
COLLECT_SHAPES: List[Tuple[int, int, int, int]] = [
    (64, 64, 64, 1),
    (256, 256, 256, 1),
    (512, 784, 512, 1),
    (256, 2304, 392, 1),
    (32, 2048, 27, 1),
    (1, 4096, 1000, 1),
    (3136, 27, 64, 1),
    (1024, 512, 256, 1),
]


def serving_bucket_shapes(network: str) -> List[Tuple[int, int, int, int]]:
    """GEMM shape buckets the serving coordinator supports: the network's
    own layer GEMMs plus a few generic power-of-two buckets."""
    shapes = []
    for spec in M.network_layers(network):
        shapes.append((spec.gemm_m, spec.gemm_k, spec.gemm_n, 1))
    shapes += [(128, 128, 128, 1), (512, 512, 512, 1), (1024, 1024, 64, 1)]
    seen, out = set(), []
    for s in shapes:
        if s not in seen:
            seen.add(s)
            out.append(s)
    return out


# ---------------------------------------------------------------------------
# Lowering helpers.
# ---------------------------------------------------------------------------


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe interchange).

    `return_tuple=False`: every artifact returns exactly one array, so the
    Rust runtime receives a plain buffer it can feed straight into the next
    executable (zero-copy layer chaining) instead of a 1-tuple.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=False
    )
    return comp.as_hlo_text()


def lower_matmul(cfg: Optional[KernelConfig], b: int, m: int, k: int, n: int) -> str:
    """Lower one GEMM executable. `cfg=None` -> XLA-dot comparator backend."""
    lhs = jax.ShapeDtypeStruct((b, m, k), jnp.float32)
    rhs = jax.ShapeDtypeStruct((b, k, n), jnp.float32)
    if cfg is None:
        fn = M.xla_backend()
    else:
        fn = M.pallas_backend(cfg)
    return to_hlo_text(jax.jit(fn).lower(lhs, rhs))


def lower_layer(spec, cfg: Optional[KernelConfig]) -> str:
    """Lower one network layer. `cfg=None` -> XLA-dot comparator backend."""
    mm = M.xla_backend() if cfg is None else M.pallas_backend(cfg)
    fn = M.layer_fn(spec, mm)
    return to_hlo_text(jax.jit(fn).lower(*M.layer_input_specs(spec)))


# ---------------------------------------------------------------------------
# Artifact bundle builder.
# ---------------------------------------------------------------------------


class Bundle:
    def __init__(self, out_dir: str, force: bool):
        self.out_dir = out_dir
        self.force = force
        self.entries: List[Dict] = []
        self._seen: set = set()
        self.lowered = 0
        self.skipped = 0
        self.t0 = time.time()

    def _write(self, rel_path: str, make_text) -> None:
        path = os.path.join(self.out_dir, rel_path)
        os.makedirs(os.path.dirname(path), exist_ok=True)
        if os.path.exists(path) and not self.force:
            self.skipped += 1
            return
        text = make_text()
        with open(path, "w") as f:
            f.write(text)
        self.lowered += 1
        if self.lowered % 50 == 0:
            rate = self.lowered / (time.time() - self.t0)
            print(
                f"  lowered {self.lowered} artifacts ({rate:.1f}/s)",
                flush=True,
            )

    def add_matmul(
        self,
        group: str,
        cfg: Optional[KernelConfig],
        b: int,
        m: int,
        k: int,
        n: int,
    ) -> None:
        cname = cfg.name if cfg is not None else "xla"
        rel = f"{group}/mm_{cname}_b{b}m{m}k{k}n{n}.hlo.txt"
        if rel in self._seen:
            return
        self._seen.add(rel)
        self.entries.append(
            {
                "path": rel,
                "kind": "matmul",
                "backend": "pallas" if cfg is not None else "xla",
                "config": cfg.name if cfg else None,
                "config_index": cfg.index() if cfg else None,
                "b": b,
                "m": m,
                "k": k,
                "n": n,
                "flops": 2 * b * m * k * n,
                "inputs": [[b, m, k], [b, k, n]],
                "output": [b, m, n],
            }
        )
        self._write(rel, lambda: lower_matmul(cfg, b, m, k, n))

    def add_layer(self, network: str, index: int, spec, cfg) -> None:
        cname = cfg.name if cfg is not None else "xla"
        rel = f"{network}/{spec.name}_{cname}.hlo.txt"
        if rel in self._seen:
            return
        self._seen.add(rel)
        if isinstance(spec, M.ConvSpec):
            inputs = [
                [1, spec.hw, spec.hw, spec.cin],
                [9 * spec.cin, spec.cout],
                [spec.cout],
            ]
            output = [1, spec.out_hw, spec.out_hw, spec.cout]
            kind = "conv_layer"
        else:
            inputs = [[1, spec.k], [spec.k, spec.n], [spec.n]]
            output = [1, spec.n]
            kind = "fc_layer"
        self.entries.append(
            {
                "path": rel,
                "kind": kind,
                "backend": "pallas" if cfg is not None else "xla",
                "config": cfg.name if cfg else None,
                "config_index": cfg.index() if cfg else None,
                "network": network,
                "layer": spec.name,
                "layer_index": index,
                "m": spec.gemm_m,
                "k": spec.gemm_k,
                "n": spec.gemm_n,
                "b": 1,
                "pool": bool(getattr(spec, "pool", False)),
                "relu": bool(getattr(spec, "relu", True)),
                "flops": spec.flops,
                "inputs": inputs,
                "output": output,
            }
        )
        self._write(rel, lambda: lower_layer(spec, cfg))

    def write_manifest(self, meta: Dict) -> None:
        manifest = {
            "version": 1,
            "generated_unix": int(time.time()),
            "meta": meta,
            "artifacts": self.entries,
        }
        path = os.path.join(self.out_dir, "manifest.json")
        os.makedirs(self.out_dir, exist_ok=True)
        with open(path, "w") as f:
            json.dump(manifest, f, indent=1)
        print(
            f"manifest: {len(self.entries)} artifacts "
            f"({self.lowered} lowered, {self.skipped} cached) -> {path}"
        )


def load_deploy(path: str) -> Tuple[List[KernelConfig], KernelConfig]:
    with open(path) as f:
        deploy = json.load(f)
    configs = [config_by_name(n) for n in deploy["deployed"]]
    single = config_by_name(deploy["single_best"])
    return configs, single


def main(argv: Sequence[str] = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument(
        "--deploy",
        default=os.path.join(os.path.dirname(__file__), "deploy_default.json"),
        help="JSON file naming the deployed kernel configurations",
    )
    ap.add_argument(
        "--networks",
        default="vgg16-tiny",
        help="comma-separated networks to emit per-layer artifacts for "
        "(vgg16-tiny, vgg16, or none)",
    )
    ap.add_argument(
        "--collect",
        action="store_true",
        help="also emit the full 640-config x %d-shape measured-CPU sweep"
        % len(COLLECT_SHAPES),
    )
    ap.add_argument(
        "--collect-shapes",
        type=int,
        default=len(COLLECT_SHAPES),
        help="number of collection shapes (prefix of the standard list)",
    )
    ap.add_argument("--force", action="store_true", help="re-lower everything")
    args = ap.parse_args(argv)

    configs, single = load_deploy(args.deploy)
    bundle = Bundle(args.out, args.force)

    # Quickstart + Figure-1 GEMMs for every deployed config and comparators.
    mm_cfgs: List[Optional[KernelConfig]] = [None, single] + configs
    for m_, k_, n_, b_ in QUICKSTART_SHAPES + FIG1_SHAPES:
        for cfg in mm_cfgs:
            bundle.add_matmul("matmul", cfg, b_, m_, k_, n_)

    networks = [n for n in args.networks.split(",") if n and n != "none"]
    for network in networks:
        layers = M.network_layers(network)
        # Serving buckets: deployed configs + comparators for each bucket.
        for m_, k_, n_, b_ in serving_bucket_shapes(network):
            for cfg in mm_cfgs:
                bundle.add_matmul("matmul", cfg, b_, m_, k_, n_)
        # Per-layer artifacts.
        for i, spec in enumerate(layers):
            for cfg in mm_cfgs:
                bundle.add_layer(network, i, spec, cfg)

    if args.collect:
        shapes = COLLECT_SHAPES[: args.collect_shapes]
        for m_, k_, n_, b_ in shapes:
            for cfg in all_configs():
                bundle.add_matmul("collect", cfg, b_, m_, k_, n_)

    bundle.write_manifest(
        {
            "deployed": [c.name for c in configs],
            "single_best": single.name,
            "networks": networks,
            "collect": bool(args.collect),
        }
    )


if __name__ == "__main__":
    main()
