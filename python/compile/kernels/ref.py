"""Pure-jnp reference oracle for the parameterized GEMM kernel.

This is the correctness contract for the Pallas kernel: every configuration
must match ``batched_matmul_ref`` to float tolerance for every shape.  The
pytest suite sweeps configurations and (hypothesis-generated) shapes against
this oracle.
"""

from __future__ import annotations

import jax.numpy as jnp


def batched_matmul_ref(lhs: jnp.ndarray, rhs: jnp.ndarray) -> jnp.ndarray:
    """out[b] = lhs[b] @ rhs[b] with f32 accumulation.

    Args:
      lhs: (B, M, K) array.
      rhs: (B, K, N) array.

    Returns:
      (B, M, N) array in the input dtype, accumulated in float32.
    """
    if lhs.ndim != 3 or rhs.ndim != 3:
        raise ValueError(f"expected rank-3 inputs, got {lhs.shape}, {rhs.shape}")
    if lhs.shape[0] != rhs.shape[0] or lhs.shape[2] != rhs.shape[1]:
        raise ValueError(f"shape mismatch: {lhs.shape} @ {rhs.shape}")
    out = jnp.einsum(
        "bmk,bkn->bmn",
        lhs,
        rhs,
        preferred_element_type=jnp.float32,
    )
    return out.astype(lhs.dtype)


def matmul_ref(lhs: jnp.ndarray, rhs: jnp.ndarray) -> jnp.ndarray:
    """Unbatched convenience wrapper: (M, K) @ (K, N) -> (M, N)."""
    return batched_matmul_ref(lhs[None], rhs[None])[0]
