"""Layer-1: the parameterized batched-GEMM Pallas kernel.

This is the Pallas/TPU rethink of the paper's SYCL work-group GEMM kernel
(DESIGN.md §2).  The SYCL kernel gives each work-item an R x C accumulator
tile fed by A-deep vector loads, inside a (WR, WC) work-group.  On a TPU the
analogous schedule is expressed with a BlockSpec grid:

  * the work-group's collective output tile (R*WR, C*WC) becomes the
    HBM->VMEM output block ``(block_m, block_n)``;
  * the A-deep per-iteration loads become the depth of the K pipeline: the
    kernel marches over K in VMEM chunks of ``k_chunk = A * K_UNIT``,
    accumulating into a float32 VMEM accumulator (the MXU-friendly layout).

All 640 configurations therefore lower to genuinely different HLO: block
shapes, K-loop trip counts and VMEM working sets all differ, which is what
the selection problem is about.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, and interpret-mode lowers the kernel to portable HLO that the
Rust runtime compiles and runs.  Real-TPU viability per config is estimated
analytically (DESIGN.md §8).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .config import KernelConfig


# K-pipeline steps at or below this are unrolled into straight-line dots at
# trace time. Unrolled slabs use static slices that XLA fuses and schedules
# much better than a `fori_loop` body (≈ +20% on the CPU PJRT backend);
# the cap bounds the lowered HLO size for deep-K problems.
UNROLL_MAX_STEPS: int = 16


def _matmul_kernel(lhs_ref, rhs_ref, out_ref, *, k_chunk: int, out_dtype):
    """Kernel body for one (batch, m-block, n-block) grid cell.

    Refs:
      lhs_ref: (1, block_m, K) VMEM block of the left operand.
      rhs_ref: (1, K, block_n) VMEM block of the right operand.
      out_ref: (1, block_m, block_n) output block.
    """
    block_m = lhs_ref.shape[1]
    block_n = rhs_ref.shape[2]
    k_total = lhs_ref.shape[2]
    num_steps = k_total // k_chunk

    def body(step, acc):
        # One A-depth slab of the K pipeline: load (block_m, k_chunk) and
        # (k_chunk, block_n) strips and accumulate their product in f32.
        lhs_slab = pl.load(
            lhs_ref, (0, slice(None), pl.dslice(step * k_chunk, k_chunk))
        )
        rhs_slab = pl.load(
            rhs_ref, (0, pl.dslice(step * k_chunk, k_chunk), slice(None))
        )
        return acc + jax.lax.dot_general(
            lhs_slab,
            rhs_slab,
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    acc = jnp.zeros((block_m, block_n), jnp.float32)
    if num_steps <= UNROLL_MAX_STEPS:
        for step in range(num_steps):
            acc = body(step, acc)
    else:
        acc = jax.lax.fori_loop(0, num_steps, body, acc)
    out_ref[0, :, :] = acc.astype(out_dtype)


def round_up(x: int, mult: int) -> int:
    """Smallest multiple of `mult` that is >= x."""
    return ((x + mult - 1) // mult) * mult


def padded_dims(cfg: KernelConfig, m: int, k: int, n: int):
    """The (M, K, N) the kernel actually runs for logical dims (m, k, n)."""
    return (
        round_up(m, cfg.block_m),
        round_up(k, cfg.k_chunk),
        round_up(n, cfg.block_n),
    )


@functools.partial(
    jax.jit, static_argnames=("acc_r", "acc_a", "acc_c", "wg_r", "wg_c")
)
def _matmul_padded(lhs, rhs, *, acc_r, acc_a, acc_c, wg_r, wg_c):
    """Pallas GEMM over already-padded operands.

    lhs: (B, M, K) with M % block_m == 0 and K % k_chunk == 0.
    rhs: (B, K, N) with N % block_n == 0.
    """
    cfg = KernelConfig(acc_r, acc_a, acc_c, wg_r, wg_c)
    batch, m, k = lhs.shape
    _, _, n = rhs.shape
    bm, bn, kc = cfg.block_m, cfg.block_n, cfg.k_chunk
    if m % bm or k % kc or n % bn:
        raise ValueError(
            f"operands not padded for {cfg.name}: "
            f"m={m} (bm={bm}), k={k} (kc={kc}), n={n} (bn={bn})"
        )
    grid = (batch, m // bm, n // bn)
    kernel = functools.partial(
        _matmul_kernel, k_chunk=kc, out_dtype=lhs.dtype
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, k), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, k, bn), lambda b, i, j: (b, 0, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda b, i, j: (b, i, j)),
        out_shape=jax.ShapeDtypeStruct((batch, m, n), lhs.dtype),
        interpret=True,
    )(lhs, rhs)


def batched_matmul(
    lhs: jnp.ndarray, rhs: jnp.ndarray, cfg: KernelConfig
) -> jnp.ndarray:
    """out[b] = lhs[b] @ rhs[b] using kernel configuration `cfg`.

    Operands of any (B, M, K) x (B, K, N) shape; they are zero-padded up to
    the configuration's block multiples (zero padding is exact for matmul)
    and the result is sliced back.  The padding waste is part of the cost a
    configuration pays on awkward shapes -- exactly the under-utilisation
    effect the paper observes for tall-skinny inputs.
    """
    if lhs.ndim != 3 or rhs.ndim != 3:
        raise ValueError(f"expected rank-3 inputs, got {lhs.shape}, {rhs.shape}")
    batch, m, k = lhs.shape
    batch2, k2, n = rhs.shape
    if batch != batch2 or k != k2:
        raise ValueError(f"shape mismatch: {lhs.shape} @ {rhs.shape}")
    mp, kp, np_ = padded_dims(cfg, m, k, n)
    lhs_p = jnp.pad(lhs, ((0, 0), (0, mp - m), (0, kp - k)))
    rhs_p = jnp.pad(rhs, ((0, 0), (0, kp - k), (0, np_ - n)))
    out = _matmul_padded(
        lhs_p,
        rhs_p,
        acc_r=cfg.acc_r,
        acc_a=cfg.acc_a,
        acc_c=cfg.acc_c,
        wg_r=cfg.wg_r,
        wg_c=cfg.wg_c,
    )
    return out[:, :m, :n]


def matmul(lhs: jnp.ndarray, rhs: jnp.ndarray, cfg: KernelConfig) -> jnp.ndarray:
    """Unbatched convenience wrapper."""
    return batched_matmul(lhs[None], rhs[None], cfg)[0]
