"""Layer-1 Pallas kernels and their configuration space."""

from .config import (  # noqa: F401
    KernelConfig,
    NUM_CONFIGS,
    TILE_SIZES,
    WORKGROUPS,
    all_configs,
    config_by_index,
    config_by_name,
    iter_configs,
)
from .matmul import batched_matmul, matmul, padded_dims  # noqa: F401
from .ref import batched_matmul_ref, matmul_ref  # noqa: F401
