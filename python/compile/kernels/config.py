"""The kernel configuration space of the paper (§3).

The paper's SYCL GEMM kernel has three compile-time micro-tile parameters
(R, A, C) -- each work-item accumulates an R x C output tile from R x A and
A x C vector loads -- and a 2-D work-group size (WR, WC).  Tile parameters
take values in {1, 2, 4, 8} (64 combinations) and 10 work-group pairings are
legal, giving 640 configurations total.

Pallas / TPU adaptation (DESIGN.md §2):
  * The work-group times the micro-tile gives the HBM->VMEM block shape the
    kernel schedules over: ``block_m = R * WR`` and ``block_n = C * WC``.
  * The A-depth of the work-item loads becomes the depth of the VMEM K
    pipeline: the kernel marches over K in chunks of ``k_chunk = A * K_UNIT``
    so A genuinely changes the working set and the loop trip count, just as
    it changes the per-iteration load depth in the SYCL kernel.

This module is the single Python source of truth for the space; the Rust
``dataset::config`` module mirrors it exactly (checked by a golden test on
the manifest).
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, List, Tuple

TILE_SIZES: Tuple[int, ...] = (1, 2, 4, 8)

# Legal (rows, cols) work-group pairings from the paper (§3): products are
# capped by device work-group limits, so only these ten are used.
WORKGROUPS: Tuple[Tuple[int, int], ...] = (
    (1, 64),
    (1, 128),
    (8, 8),
    (8, 16),
    (8, 32),
    (16, 8),
    (16, 16),
    (32, 8),
    (64, 1),
    (128, 1),
)

# One unit of K-chunk depth per unit of the A tile parameter.  A in {1,2,4,8}
# therefore gives K chunks of {32, 64, 128, 256} -- small enough for VMEM,
# large enough that the fori_loop trip count differs meaningfully per config.
K_UNIT: int = 32


@dataclasses.dataclass(frozen=True)
class KernelConfig:
    """One point in the 640-point kernel configuration space."""

    acc_r: int  # R: rows of the per-work-item accumulator tile
    acc_a: int  # A: depth of the per-iteration loads
    acc_c: int  # C: cols of the per-work-item accumulator tile
    wg_r: int   # work-group rows
    wg_c: int   # work-group cols

    @property
    def block_m(self) -> int:
        """Rows of the HBM->VMEM output block (work-group x micro-tile)."""
        return self.acc_r * self.wg_r

    @property
    def block_n(self) -> int:
        """Cols of the HBM->VMEM output block."""
        return self.acc_c * self.wg_c

    @property
    def k_chunk(self) -> int:
        """Depth of one K step of the VMEM pipeline."""
        return self.acc_a * K_UNIT

    @property
    def name(self) -> str:
        return (
            f"r{self.acc_r}a{self.acc_a}c{self.acc_c}"
            f"_wg{self.wg_r}x{self.wg_c}"
        )

    def index(self) -> int:
        """Stable index of this config in `all_configs()` ordering."""
        ti = (
            TILE_SIZES.index(self.acc_r) * len(TILE_SIZES) * len(TILE_SIZES)
            + TILE_SIZES.index(self.acc_a) * len(TILE_SIZES)
            + TILE_SIZES.index(self.acc_c)
        )
        wi = WORKGROUPS.index((self.wg_r, self.wg_c))
        return ti * len(WORKGROUPS) + wi

    def vmem_bytes(self, dtype_bytes: int = 4) -> int:
        """Estimated VMEM working set: lhs + rhs K-chunk strips + f32 acc."""
        lhs = self.block_m * self.k_chunk * dtype_bytes
        rhs = self.k_chunk * self.block_n * dtype_bytes
        acc = self.block_m * self.block_n * 4
        return lhs + rhs + acc


def all_configs() -> List[KernelConfig]:
    """The full 640-configuration space in stable index order."""
    return list(iter_configs())


def iter_configs() -> Iterator[KernelConfig]:
    for r in TILE_SIZES:
        for a in TILE_SIZES:
            for c in TILE_SIZES:
                for wr, wc in WORKGROUPS:
                    yield KernelConfig(r, a, c, wr, wc)


def config_by_index(idx: int) -> KernelConfig:
    n_wg = len(WORKGROUPS)
    ti, wi = divmod(idx, n_wg)
    ri, rem = divmod(ti, len(TILE_SIZES) * len(TILE_SIZES))
    ai, ci = divmod(rem, len(TILE_SIZES))
    wr, wc = WORKGROUPS[wi]
    return KernelConfig(TILE_SIZES[ri], TILE_SIZES[ai], TILE_SIZES[ci], wr, wc)


def config_by_name(name: str) -> KernelConfig:
    for cfg in iter_configs():
        if cfg.name == name:
            return cfg
    raise KeyError(f"no such kernel config: {name!r}")


NUM_CONFIGS: int = len(TILE_SIZES) ** 3 * len(WORKGROUPS)
assert NUM_CONFIGS == 640
